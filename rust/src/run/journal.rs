//! Crash-safe run journal: `cprune-run-journal` v1 (DESIGN.md §15).
//!
//! A journaled run appends one JSONL record per recovery barrier —
//! run config, baseline, each accepted pruning iteration — and fsyncs
//! at every barrier, so a crash loses at most the in-flight iteration.
//! Each iteration record carries the accepted [`Checkpoint`] (the
//! channels map / frontier point), the gates it was judged against, and
//! the *tune-cache delta* since the previous barrier, in the exact
//! entry shape [`TuneCache::to_json`] uses.
//!
//! **Resume invariant** (pinned by `rust/tests/journal_tests.rs` and
//! the `crash-resume` CI job): a run is a pure function of
//! seed + tune cache, so `cprune run --resume <journal>` rebuilds the
//! run config from the journal, preloads every journaled cache entry,
//! and re-executes from iteration 0 — the pre-crash iterations replay
//! as pure cache hits, and the full [`super::RunEvent`] JSONL comes out
//! **byte-identical** to an uninterrupted run's. Already-journaled
//! barriers are suppressed on replay; the first live barrier captures
//! exactly the entries tuned after the crash point.
//!
//! Crash-safety of the journal file itself: records are appended with
//! `write_all` + `sync_all`, so the only malformed state a crash can
//! leave is a torn final line. [`RunJournal::resume`] truncates that
//! torn tail before appending a `resumed` marker; any damage *before*
//! the final newline is corruption and refuses to resume (and
//! `cprune check` flags it as CPV16x).

use crate::serve::Checkpoint;
use crate::tuner::TuneCache;
use crate::util::fault;
use crate::util::json::{self, Json};
use std::collections::HashSet;
use std::io::Write;
use std::path::{Path, PathBuf};

/// Format tag of the journal header line.
pub const JOURNAL_FORMAT: &str = "cprune-run-journal";
/// Bump when the record schema changes; `resume` rejects other versions.
pub const JOURNAL_VERSION: u64 = 1;

/// The run configuration a journal pins — everything `--resume` needs
/// to rebuild the run besides the cache entries (model/pruner/device
/// are the CLI-level tokens, so the resumed process resolves them the
/// same way the original invocation did).
#[derive(Clone, Debug, PartialEq)]
pub struct JournalConfig {
    /// Run seed (`--seed`).
    pub seed: u64,
    /// Pruner registry token (`--pruner`).
    pub pruner: String,
    /// Model token (`--model`).
    pub model: String,
    /// Device or remote-target token (`--device` / `--target`).
    pub device: String,
    /// Iteration budget (`--iters`).
    pub iters: usize,
    /// Optional accuracy budget (`--target-acc`).
    pub target_acc: Option<f64>,
}

impl JournalConfig {
    /// Serialize as the journal's `config` record.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("record", Json::Str("config".to_string())),
            ("seed", Json::Num(self.seed as f64)),
            ("pruner", Json::Str(self.pruner.clone())),
            ("model", Json::Str(self.model.clone())),
            ("device", Json::Str(self.device.clone())),
            ("iters", Json::Num(self.iters as f64)),
            ("target_acc", self.target_acc.map(Json::Num).unwrap_or(Json::Null)),
        ])
    }

    /// Parse a `config` record.
    pub fn from_json(j: &Json) -> Result<JournalConfig, String> {
        let str_field = |k: &str| {
            j.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| format!("config record missing '{k}'"))
        };
        let num_field = |k: &str| {
            j.get(k).and_then(Json::as_usize).ok_or_else(|| format!("config record missing '{k}'"))
        };
        Ok(JournalConfig {
            seed: num_field("seed")? as u64,
            pruner: str_field("pruner")?,
            model: str_field("model")?,
            device: str_field("device")?,
            iters: num_field("iters")?,
            target_acc: match j.get("target_acc") {
                None | Some(Json::Null) => None,
                Some(v) => {
                    Some(v.as_f64().ok_or("config record has a non-numeric 'target_acc'")?)
                }
            },
        })
    }
}

/// One accepted iteration's barrier payload — what
/// [`super::RunContext::journal_accept`] hands the journal.
#[derive(Clone, Debug)]
pub struct IterationRecord {
    /// 1-based accepted iteration number.
    pub iteration: usize,
    /// Measured latency of the accepted candidate (seconds).
    pub latency: f64,
    /// Latency target the candidate was judged against (pre-update).
    pub latency_target: f64,
    /// Short-train accuracy of the accepted candidate.
    pub short_accuracy: f64,
    /// Accuracy gate the candidate was judged against (pre-update).
    pub accuracy_gate: f64,
    /// Filters removed from the chosen layer this iteration.
    pub filters_removed: usize,
    /// Candidate layers evaluated before one was accepted.
    pub candidates_tried: usize,
    /// The accepted frontier point (channels map included).
    pub checkpoint: Checkpoint,
}

/// What [`RunJournal::resume`] recovered from an interrupted journal:
/// the pinned config plus every journaled tune-cache entry, ready to
/// warm-start the re-execution.
pub struct ResumeState {
    /// Run configuration pinned by the journal's `config` record.
    pub config: JournalConfig,
    /// Last iteration with a journaled barrier (0 = baseline only).
    pub last_iteration: usize,
    entries: Vec<Json>,
}

impl ResumeState {
    /// Merge every journaled tune-cache entry into `cache` — the warm
    /// start that makes pre-crash iterations replay as pure hits.
    pub fn preload(&self, cache: &TuneCache) -> Result<(), String> {
        for e in &self.entries {
            cache.merge_entry_json(e).map_err(|err| format!("journaled cache entry: {err}"))?;
        }
        Ok(())
    }

    /// Number of journaled cache entries recovered.
    pub fn entry_count(&self) -> usize {
        self.entries.len()
    }
}

/// Append-only writer for one run's journal.
///
/// Journal failures never abort a run mid-flight (the run itself is the
/// valuable computation); the first append error is latched and the run
/// surfaces it as its own failure once finished — see
/// [`RunJournal::error`].
pub struct RunJournal {
    path: PathBuf,
    file: std::fs::File,
    /// Canonical workload keys already journaled — the complement of
    /// the next barrier's cache delta.
    known: HashSet<String>,
    /// Barriers for iterations `<= skip_through` are suppressed: they
    /// were journaled before the crash and replay as cache hits.
    skip_through: usize,
    baseline_logged: bool,
    finished: bool,
    error: Option<String>,
}

impl RunJournal {
    /// Start a fresh journal at `path`: writes and fsyncs the header and
    /// `config` records (truncating any previous journal there).
    pub fn create(path: impl Into<PathBuf>, config: &JournalConfig) -> Result<RunJournal, String> {
        let path = path.into();
        // OpenOptions rather than File::create: the journal is an append
        // log, not an atomic_write document (CPL007 sanctions only the
        // latter outside util/io.rs).
        let file = std::fs::OpenOptions::new()
            .write(true)
            .create(true)
            .truncate(true)
            .open(&path)
            .map_err(|e| format!("{}: cannot create journal: {e}", path.display()))?;
        let mut j = RunJournal {
            path,
            file,
            known: HashSet::new(),
            skip_through: 0,
            baseline_logged: false,
            finished: false,
            error: None,
        };
        j.append_json(&Json::obj(vec![
            ("format", Json::Str(JOURNAL_FORMAT.to_string())),
            ("version", Json::Num(JOURNAL_VERSION as f64)),
        ]));
        j.append_json(&config.to_json());
        match j.error.take() {
            Some(e) => Err(e),
            None => Ok(j),
        }
    }

    /// Reopen an interrupted journal for appending: parses the intact
    /// prefix, truncates a torn final line (the expected shape of a
    /// crash mid-append), appends a `resumed` marker, and returns the
    /// recovered [`ResumeState`]. Refuses corruption before the final
    /// newline, a finished run, and foreign/other-version documents.
    pub fn resume(path: impl Into<PathBuf>) -> Result<(RunJournal, ResumeState), String> {
        let path = path.into();
        let label = path.display().to_string();
        let bytes =
            std::fs::read(&path).map_err(|e| format!("{label}: cannot read journal: {e}"))?;
        let keep = bytes.iter().rposition(|&b| b == b'\n').map(|i| i + 1).unwrap_or(0);
        let intact = std::str::from_utf8(&bytes[..keep])
            .map_err(|_| format!("{label}: journal prefix is not UTF-8"))?;
        let parsed = parse_journal(intact, &label)?;
        if parsed.finished {
            return Err(format!("{label}: journal records a finished run — nothing to resume"));
        }
        let file = std::fs::OpenOptions::new()
            .write(true)
            .append(true)
            .open(&path)
            .map_err(|e| format!("{label}: cannot reopen journal: {e}"))?;
        if (keep as u64) < bytes.len() as u64 {
            // Drop the torn tail so the resumed log stays valid JSONL.
            file.set_len(keep as u64)
                .map_err(|e| format!("{label}: cannot truncate torn tail: {e}"))?;
        }
        let mut known = HashSet::new();
        for e in &parsed.entries {
            if let Some(w) = e.get("workload") {
                known.insert(w.to_string());
            }
        }
        let mut j = RunJournal {
            path,
            file,
            known,
            skip_through: parsed.last_iteration,
            baseline_logged: parsed.baseline_logged,
            finished: false,
            error: None,
        };
        j.append_json(&Json::obj(vec![
            ("record", Json::Str("resumed".to_string())),
            ("from_iteration", Json::Num(parsed.last_iteration as f64)),
        ]));
        if let Some(e) = j.error.take() {
            return Err(e);
        }
        let state = ResumeState {
            config: parsed.config,
            last_iteration: parsed.last_iteration,
            entries: parsed.entries,
        };
        Ok((j, state))
    }

    /// Journal path (diagnostics).
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// First append failure, if any — checked by the run after finishing
    /// so a journaled run never claims success with a broken journal.
    pub fn error(&self) -> Option<&str> {
        self.error.as_deref()
    }

    /// Baseline barrier: the run's pre-pruning measurement plus every
    /// cache entry the baseline tuning produced. Suppressed on resume
    /// replay (the baseline is already journaled). May abort at barrier
    /// site `baseline` under `--faults`.
    pub fn record_baseline(&mut self, latency: f64, fps: f64, events: usize, cache: &TuneCache) {
        if self.finished || self.baseline_logged {
            return;
        }
        self.baseline_logged = true;
        let delta = self.take_delta(cache);
        self.append_json(&Json::obj(vec![
            ("record", Json::Str("baseline".to_string())),
            ("latency", Json::Num(latency)),
            ("fps", Json::Num(fps)),
            ("events", Json::Num(events as f64)),
            ("cache", delta),
        ]));
        fault::at_barrier("baseline");
    }

    /// Accepted-iteration barrier: the accepted checkpoint, the gates it
    /// passed, measurement/event counters, and the cache delta since the
    /// previous barrier. Suppressed on resume replay for iterations that
    /// were journaled before the crash. May abort at barrier site
    /// `iter:N` under `--faults`.
    pub fn record_iteration(
        &mut self,
        rec: &IterationRecord,
        programs_measured: usize,
        events: usize,
        cache: &TuneCache,
    ) {
        if self.finished || rec.iteration <= self.skip_through {
            return;
        }
        self.skip_through = rec.iteration;
        let delta = self.take_delta(cache);
        self.append_json(&Json::obj(vec![
            ("record", Json::Str("iteration".to_string())),
            ("iteration", Json::Num(rec.iteration as f64)),
            ("latency", Json::Num(rec.latency)),
            ("latency_target", Json::Num(rec.latency_target)),
            ("short_accuracy", Json::Num(rec.short_accuracy)),
            ("accuracy_gate", Json::Num(rec.accuracy_gate)),
            ("filters_removed", Json::Num(rec.filters_removed as f64)),
            ("candidates_tried", Json::Num(rec.candidates_tried as f64)),
            ("checkpoint", rec.checkpoint.to_json()),
            ("programs_measured", Json::Num(programs_measured as f64)),
            ("events", Json::Num(events as f64)),
            ("cache", delta),
        ]));
        fault::at_barrier(&format!("iter:{}", rec.iteration));
    }

    /// Final barrier: the run completed; `events` is the total RunEvent
    /// count including `Finished`. A finished journal refuses `resume`.
    /// May abort at barrier site `finish` under `--faults`.
    pub fn record_finished(&mut self, events: usize) {
        if self.finished {
            return;
        }
        self.finished = true;
        self.append_json(&Json::obj(vec![
            ("record", Json::Str("finished".to_string())),
            ("events", Json::Num(events as f64)),
        ]));
        fault::at_barrier("finish");
    }

    /// Cache entries not yet journaled, consumed into the next record.
    fn take_delta(&mut self, cache: &TuneCache) -> Json {
        let fresh = cache.entries_not_in(&self.known);
        let mut arr = Vec::with_capacity(fresh.len());
        for (key, entry) in fresh {
            self.known.insert(key);
            arr.push(entry);
        }
        Json::Arr(arr)
    }

    /// Append one record line and fsync it (the journal's durability
    /// barrier). Consults the fault hook at site `journal`: an injected
    /// tear writes a partial line with no trailing newline — exactly the
    /// state a mid-append crash leaves — and latches the error.
    fn append_json(&mut self, record: &Json) {
        if self.error.is_some() {
            return;
        }
        let mut line = record.to_string();
        line.push('\n');
        let fail = |e: String| format!("{}: {e}", self.path.display());
        match fault::write_fault("journal") {
            Some(fault::WriteFault::FailBefore) => {
                self.error = Some(fail("injected journal write failure".to_string()));
                return;
            }
            Some(fault::WriteFault::Torn { keep }) => {
                let keep = keep.min(line.len().saturating_sub(1));
                let _ = self.file.write_all(&line.as_bytes()[..keep]);
                let _ = self.file.sync_all();
                self.error = Some(fail("injected torn journal append".to_string()));
                return;
            }
            None => {}
        }
        if let Err(e) = self.file.write_all(line.as_bytes()) {
            self.error = Some(fail(format!("journal append failed: {e}")));
            return;
        }
        if let Err(e) = self.file.sync_all() {
            self.error = Some(fail(format!("journal fsync failed: {e}")));
        }
    }
}

/// Read only the `config` record of a journal (what `cprune run
/// --resume` uses to rebuild the CLI configuration before the run
/// itself reopens the journal for appending).
pub fn read_config(path: impl AsRef<Path>) -> Result<JournalConfig, String> {
    let path = path.as_ref();
    let label = path.display().to_string();
    let bytes = std::fs::read(path).map_err(|e| format!("{label}: cannot read journal: {e}"))?;
    let keep = bytes.iter().rposition(|&b| b == b'\n').map(|i| i + 1).unwrap_or(0);
    let intact = std::str::from_utf8(&bytes[..keep])
        .map_err(|_| format!("{label}: journal prefix is not UTF-8"))?;
    Ok(parse_journal(intact, &label)?.config)
}

/// Parsed intact prefix of a journal.
struct ParsedJournal {
    config: JournalConfig,
    entries: Vec<Json>,
    last_iteration: usize,
    baseline_logged: bool,
    finished: bool,
}

/// Strict reader for the intact (newline-terminated) prefix of a
/// journal. A torn *final* line is the caller's problem (it is sliced
/// off before this runs); anything malformed in the intact prefix is
/// corruption, not a crash artifact, and errors out.
fn parse_journal(intact: &str, label: &str) -> Result<ParsedJournal, String> {
    let mut lines = intact.lines().filter(|l| !l.trim().is_empty());
    let header = lines.next().ok_or_else(|| format!("{label}: journal has no header"))?;
    let h = json::parse(header).map_err(|e| format!("{label}: bad journal header: {e}"))?;
    match h.get("format").and_then(Json::as_str) {
        Some(JOURNAL_FORMAT) => {}
        other => return Err(format!("{label}: not a run journal (format {other:?})")),
    }
    match h.get("version").and_then(Json::as_usize) {
        Some(v) if v as u64 == JOURNAL_VERSION => {}
        other => {
            return Err(format!(
                "{label}: unsupported journal version {other:?} (want {JOURNAL_VERSION})"
            ))
        }
    }
    let cline = lines.next().ok_or_else(|| format!("{label}: journal has no config record"))?;
    let cj = json::parse(cline).map_err(|e| format!("{label}: bad config record: {e}"))?;
    if cj.get("record").and_then(Json::as_str) != Some("config") {
        return Err(format!("{label}: first journal record must be 'config'"));
    }
    let config = JournalConfig::from_json(&cj).map_err(|e| format!("{label}: {e}"))?;
    let mut entries = Vec::new();
    let mut last_iteration = 0usize;
    let mut baseline_logged = false;
    let mut finished = false;
    for line in lines {
        if finished {
            return Err(format!("{label}: journal record after 'finished'"));
        }
        let j = json::parse(line)
            .map_err(|e| format!("{label}: corrupt journal record (not a torn tail): {e}"))?;
        let collect = |j: &Json, entries: &mut Vec<Json>| -> Result<(), String> {
            let arr = j
                .get("cache")
                .and_then(Json::as_arr)
                .ok_or_else(|| format!("{label}: journal record missing cache delta"))?;
            entries.extend(arr.iter().cloned());
            Ok(())
        };
        match j.get("record").and_then(Json::as_str) {
            Some("baseline") => {
                baseline_logged = true;
                collect(&j, &mut entries)?;
            }
            Some("iteration") => {
                let n = j
                    .get("iteration")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| format!("{label}: iteration record missing number"))?;
                last_iteration = last_iteration.max(n);
                collect(&j, &mut entries)?;
            }
            Some("resumed") => {}
            Some("finished") => finished = true,
            other => return Err(format!("{label}: unknown journal record {other:?}")),
        }
    }
    Ok(ParsedJournal { config, entries, last_iteration, baseline_logged, finished })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> JournalConfig {
        JournalConfig {
            seed: 7,
            pruner: "cprune".to_string(),
            model: "resnet8-cifar".to_string(),
            device: "kryo385".to_string(),
            iters: 3,
            target_acc: None,
        }
    }

    fn tmp_path(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("cprune-journal-{}-{name}", std::process::id()))
    }

    fn checkpoint() -> Checkpoint {
        Checkpoint {
            iteration: 1,
            latency: 0.5,
            accuracy: 0.9,
            channels: [(0, 16)].into_iter().collect(),
            schemes: Default::default(),
        }
    }

    #[test]
    fn config_round_trips() {
        let c = cfg();
        assert_eq!(JournalConfig::from_json(&c.to_json()).unwrap(), c);
        let with_acc = JournalConfig { target_acc: Some(0.75), ..cfg() };
        assert_eq!(JournalConfig::from_json(&with_acc.to_json()).unwrap(), with_acc);
    }

    #[test]
    fn create_resume_round_trip_preserves_progress() {
        let path = tmp_path("roundtrip.journal");
        let cache = TuneCache::new();
        {
            let mut j = RunJournal::create(&path, &cfg()).unwrap();
            j.record_baseline(1.5, 2.0, 3, &cache);
            let rec = IterationRecord {
                iteration: 1,
                latency: 1.2,
                latency_target: 1.4,
                short_accuracy: 0.91,
                accuracy_gate: 0.89,
                filters_removed: 4,
                candidates_tried: 2,
                checkpoint: checkpoint(),
            };
            j.record_iteration(&rec, 10, 9, &cache);
            assert!(j.error().is_none());
        }
        assert_eq!(read_config(&path).unwrap(), cfg());
        let (j, state) = RunJournal::resume(&path).unwrap();
        assert_eq!(state.config, cfg());
        assert_eq!(state.last_iteration, 1);
        assert_eq!(state.entry_count(), 0);
        assert!(j.error().is_none());
        drop(j);
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"record\":\"resumed\""));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn resume_truncates_a_torn_tail() {
        let path = tmp_path("torn.journal");
        {
            let mut j = RunJournal::create(&path, &cfg()).unwrap();
            j.record_baseline(1.5, 2.0, 3, &TuneCache::new());
        }
        let intact = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, format!("{intact}{{\"record\":\"iterat")).unwrap();
        let (j, state) = RunJournal::resume(&path).unwrap();
        drop(j);
        assert_eq!(state.last_iteration, 0);
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(!text.contains("iterat\n"), "torn tail must be dropped: {text}");
        assert!(text.ends_with("\"record\":\"resumed\"}\n") || text.contains("resumed"));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn resume_refuses_finished_and_corrupt_journals() {
        let path = tmp_path("refuse.journal");
        {
            let mut j = RunJournal::create(&path, &cfg()).unwrap();
            j.record_finished(12);
        }
        let e = RunJournal::resume(&path).unwrap_err();
        assert!(e.contains("finished"), "{e}");
        // corruption before the final newline is not a torn tail
        let intact = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, format!("not json\n{intact}")).unwrap();
        assert!(RunJournal::resume(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn replayed_barriers_are_suppressed() {
        let path = tmp_path("suppress.journal");
        let cache = TuneCache::new();
        {
            let mut j = RunJournal::create(&path, &cfg()).unwrap();
            j.record_baseline(1.5, 2.0, 3, &cache);
            let rec = IterationRecord {
                iteration: 1,
                latency: 1.2,
                latency_target: 1.4,
                short_accuracy: 0.91,
                accuracy_gate: 0.89,
                filters_removed: 4,
                candidates_tried: 2,
                checkpoint: checkpoint(),
            };
            j.record_iteration(&rec, 10, 9, &cache);
        }
        let before = std::fs::read_to_string(&path).unwrap().lines().count();
        {
            let (mut j, _state) = RunJournal::resume(&path).unwrap();
            // replayed barriers: baseline + iteration 1 must not re-append
            j.record_baseline(1.5, 2.0, 3, &cache);
            let rec = IterationRecord {
                iteration: 1,
                latency: 1.2,
                latency_target: 1.4,
                short_accuracy: 0.91,
                accuracy_gate: 0.89,
                filters_removed: 4,
                candidates_tried: 2,
                checkpoint: checkpoint(),
            };
            j.record_iteration(&rec, 10, 9, &cache);
            let live = IterationRecord { iteration: 2, ..rec };
            j.record_iteration(&live, 12, 15, &cache);
            assert!(j.error().is_none());
        }
        let text = std::fs::read_to_string(&path).unwrap();
        // one `resumed` + one live iteration on top of the original log
        assert_eq!(text.lines().count(), before + 2, "{text}");
        assert_eq!(text.matches("\"record\":\"iteration\"").count(), 2);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn torn_append_latches_an_error_and_resume_recovers() {
        let path = tmp_path("torn-append.journal");
        let cache = TuneCache::new();
        {
            let mut j = RunJournal::create(&path, &cfg()).unwrap();
            // tear the NEXT journal append (create already wrote twice)
            let _guard = crate::util::fault::install(Box::new(
                crate::util::fault::FaultPlan::parse("seed:5,torn@journal").unwrap(),
            ));
            j.record_baseline(1.5, 2.0, 3, &cache);
            assert!(j.error().is_some(), "torn append must latch an error");
        }
        // the torn baseline line has no newline; resume drops it
        let (j, state) = RunJournal::resume(&path).unwrap();
        drop(j);
        assert_eq!(state.last_iteration, 0);
        let _ = std::fs::remove_file(&path);
    }
}
