//! Fluent construction of pruning runs (DESIGN.md §9, §11).
//!
//! [`RunBuilder`] owns the cross-cutting wiring that every experiment
//! harness, CLI path and bench used to hand-assemble: the model, the
//! target device (any measurement provider behind
//! [`crate::device::Target`]), the tuning budget, the RNG seed, an
//! optional warm-start cache file, the accuracy budget, the oracle and
//! the observers.
//!
//! ```no_run
//! use cprune::graph::model_zoo::ModelKind;
//! use cprune::run::{CPrune, RunBuilder};
//!
//! let mut run = RunBuilder::new(ModelKind::ResNet18Cifar)
//!     .device("kryo585")
//!     .seed(7)
//!     .cache("kryo585.cache.json")
//!     .build()
//!     .unwrap();
//! let outcome = run.execute(&CPrune::default()).unwrap();
//! println!("{:.2}x FPS", outcome.fps_increase_rate);
//! ```
//!
//! Device selection goes through the [`crate::device::TargetRegistry`]
//! (built-ins plus `CPRUNE_DEVICES` device files): [`RunBuilder::device`]
//! and [`RunBuilder::target_name`] resolve names (the latter also
//! accepts an `analytic:`/`lut:`/`remote:` provider prefix),
//! [`RunBuilder::target`] injects any provider directly, and
//! [`RunBuilder::record_trace`]/[`RunBuilder::replay_trace`] wrap the run
//! in the record/replay provider for deterministic cross-machine replays.
//! `remote:` targets (DESIGN.md §14) measure on a pool of out-of-process
//! workers — [`RunBuilder::workers`] sizes the pool,
//! [`RunBuilder::remote_trace`] records its wire-level measurements.

use super::journal::{JournalConfig, RunJournal};
use super::{PruneOutcome, Pruner, RunContext, RunObserver};
use crate::accuracy::{AccuracyOracle, ProxyOracle};
use crate::device::calibration::{self, CalibrationTable};
use crate::device::remote::{load_trace_target, RemoteOptions, RemoteTarget};
use crate::device::replay::Divergence;
use crate::device::{AnalyticTarget, DeviceSpec, LutTarget, ReplayTarget, Target, TargetRegistry};
use crate::graph::model_zoo::{Model, ModelKind};
use crate::tuner::{TuneCache, TuneOptions, TuningSession};
use std::path::PathBuf;

/// How the run's measurement provider is produced at build time.
enum TargetChoice {
    /// Analytic provider over this spec (the default: Kryo 385).
    Spec(DeviceSpec),
    /// LUT provider: per-layer tables built for the run's model at
    /// [`RunBuilder::build`] (tuning each prunable family at sampled
    /// channel counts — a deliberate upfront cost).
    Lut(DeviceSpec),
    /// Caller-supplied provider, used as-is.
    Explicit(Box<dyn Target>),
    /// Replay provider loaded from a recorded trace.
    Replay(PathBuf),
    /// Remote worker pool (DESIGN.md §14): stdio subprocess workers when
    /// `endpoints` is empty (pool size from [`RunBuilder::workers`]),
    /// one TCP connection per endpoint otherwise. `spec` is the
    /// registry-resolved device the pool's Hello replies must match.
    Remote { spec: DeviceSpec, device: String, endpoints: Vec<String> },
}

/// Builder for a [`Run`]. Defaults: Kryo 385 (analytic),
/// [`TuneOptions::quick`], seed 0, a jitter-free [`ProxyOracle`], no
/// cache, no observers, no trace.
pub struct RunBuilder {
    kind: ModelKind,
    choice: TargetChoice,
    target_error: Option<String>,
    registry: Option<TargetRegistry>,
    calibration: Option<CalibrationTable>,
    record_path: Option<PathBuf>,
    remote_trace_path: Option<PathBuf>,
    workers: usize,
    tune_opts: TuneOptions,
    seed: u64,
    cache_path: Option<PathBuf>,
    accuracy_budget: Option<f64>,
    max_iterations: Option<usize>,
    observers: Vec<Box<dyn RunObserver>>,
    oracle: Option<Box<dyn AccuracyOracle>>,
    journal_path: Option<PathBuf>,
    journal_config: Option<JournalConfig>,
    resume_path: Option<PathBuf>,
}

impl RunBuilder {
    pub fn new(kind: ModelKind) -> RunBuilder {
        RunBuilder {
            kind,
            choice: TargetChoice::Spec(DeviceSpec::kryo385()),
            target_error: None,
            registry: None,
            calibration: None,
            record_path: None,
            remote_trace_path: None,
            workers: 1,
            tune_opts: TuneOptions::quick(),
            seed: 0,
            cache_path: None,
            accuracy_budget: None,
            max_iterations: None,
            observers: Vec::new(),
            oracle: None,
            journal_path: None,
            journal_config: None,
            resume_path: None,
        }
    }

    /// Use this registry for [`device`](Self::device)/
    /// [`target_name`](Self::target_name) resolution instead of the
    /// default (built-ins + `CPRUNE_DEVICES`) — e.g. a registry with
    /// `--device-file` entries loaded. Set it *before* naming a device.
    pub fn with_registry(mut self, registry: TargetRegistry) -> RunBuilder {
        self.registry = Some(registry);
        self
    }

    fn resolve_spec(&mut self, name: &str) -> Option<DeviceSpec> {
        let registry = match &self.registry {
            Some(r) => r.clone(),
            None => match TargetRegistry::from_env() {
                Ok(r) => r,
                Err(e) => {
                    self.target_error = Some(e);
                    return None;
                }
            },
        };
        match registry.spec(name) {
            Some(spec) => Some(spec.clone()),
            None => {
                self.target_error = Some(registry.unknown_device_error(name));
                None
            }
        }
    }

    /// Target device by registry name (`kryo280`, `kryo385`, `kryo585`,
    /// `mali-g72`, `rtx3080`, plus anything loaded from `CPRUNE_DEVICES`
    /// device files); unknown names fail at [`build`](Self::build) with a
    /// diagnostic listing every valid name.
    pub fn device(mut self, name: &str) -> RunBuilder {
        if let Some(spec) = self.resolve_spec(name) {
            self.choice = TargetChoice::Spec(spec);
        }
        self
    }

    /// Target device by explicit spec (analytic provider).
    pub fn device_spec(mut self, spec: DeviceSpec) -> RunBuilder {
        self.choice = TargetChoice::Spec(spec);
        self
    }

    /// Target by explicit measurement provider (any [`Target`]).
    pub fn target(mut self, target: Box<dyn Target>) -> RunBuilder {
        self.choice = TargetChoice::Explicit(target);
        self
    }

    /// Target by registry name with an optional provider prefix:
    /// `NAME`/`analytic:NAME` (roofline), `lut:NAME` (calibrated
    /// per-layer tables built for the run's model at build time, analytic
    /// fallback for uncovered workloads), or `remote:NAME` /
    /// `remote:NAME@HOST:PORT[,HOST:PORT...]` (a pool of out-of-process
    /// workers, DESIGN.md §14 — spawned `cprune worker` subprocesses
    /// without addresses, TCP peers with). Unknown names fail at
    /// [`build`](Self::build) listing the registry's valid names.
    pub fn target_name(mut self, name: &str) -> RunBuilder {
        if let Some(rest) = name.strip_prefix("remote:") {
            let (bare, endpoints) = match rest.split_once('@') {
                Some((b, addrs)) => {
                    (b, addrs.split(',').filter(|a| !a.is_empty()).map(str::to_string).collect())
                }
                None => (rest, Vec::new()),
            };
            if let Some(spec) = self.resolve_spec(bare) {
                self.choice =
                    TargetChoice::Remote { spec, device: bare.to_string(), endpoints };
            }
            return self;
        }
        let (provider, bare) = match name.split_once(':') {
            Some((p, rest)) if p == "lut" || p == "analytic" => (p, rest),
            _ => ("analytic", name),
        };
        if let Some(spec) = self.resolve_spec(bare) {
            self.choice = if provider == "lut" {
                TargetChoice::Lut(spec)
            } else {
                TargetChoice::Spec(spec)
            };
        }
        self
    }

    /// Scale-fit the resolved device spec with this table (a
    /// `cprune calibrate --save` output): if the table holds an entry
    /// for the device's display name, `calibration::apply` adjusts the
    /// spec before the analytic/LUT provider is built. Devices absent
    /// from the table run uncalibrated; explicit-provider, replay and
    /// remote targets are unaffected (the replay trace carries its own
    /// spec; remote workers answer from their own device model, so
    /// scale-fitting the client's copy would only break the Hello check).
    pub fn calibration(mut self, table: CalibrationTable) -> RunBuilder {
        self.calibration = Some(table);
        self
    }

    /// Record every device measurement of the run into a
    /// `cprune-measure-trace` file, written after each
    /// [`Run::execute`].
    pub fn record_trace(mut self, path: impl Into<PathBuf>) -> RunBuilder {
        self.record_path = Some(path.into());
        self
    }

    /// Replay a recorded trace instead of measuring: the device spec
    /// comes from the trace, and the run reproduces the recorded run's
    /// results and event stream byte-for-byte (given the same model,
    /// seed and budgets). Accepts a `cprune-measure-trace` or a
    /// `cprune-remote-trace` (the format tag decides).
    pub fn replay_trace(mut self, path: impl Into<PathBuf>) -> RunBuilder {
        self.choice = TargetChoice::Replay(path.into());
        self
    }

    /// Pool size for `remote:NAME` subprocess targets (default 1; 0 is
    /// clamped to 1). Ignored for TCP endpoint lists, where each address
    /// is one worker. Never affects results — only wall-clock.
    pub fn workers(mut self, n: usize) -> RunBuilder {
        self.workers = n.max(1);
        self
    }

    /// Record every remote measurement — including the client-drawn
    /// jitter multipliers — into a `cprune-remote-trace` file, written
    /// after each [`Run::execute`]. Requires a remote target (checked at
    /// [`build`](Self::build)).
    pub fn remote_trace(mut self, path: impl Into<PathBuf>) -> RunBuilder {
        self.remote_trace_path = Some(path.into());
        self
    }

    /// Tuning effort per task (defaults to [`TuneOptions::quick`]).
    pub fn tune_opts(mut self, opts: TuneOptions) -> RunBuilder {
        self.tune_opts = opts;
        self
    }

    /// Seed for model weights and every tuning/measurement RNG stream.
    pub fn seed(mut self, seed: u64) -> RunBuilder {
        self.seed = seed;
        self
    }

    /// Warm-start cache file: loaded (if present) at build time, saved
    /// back after every [`Run::execute`].
    pub fn cache(mut self, path: impl Into<PathBuf>) -> RunBuilder {
        self.cache_path = Some(path.into());
        self
    }

    /// Accuracy budget `a_g` override for the iterative searches
    /// (CPrune's `target_accuracy`, NetAdapt's short-accuracy floor).
    /// One-shot pruners (magnitude/FPGM/AMC/PQF) have no accuracy knob
    /// and ignore it.
    pub fn accuracy_budget(mut self, floor: f64) -> RunBuilder {
        self.accuracy_budget = Some(floor);
        self
    }

    /// Iteration-cap override for the iterative searches (CPrune,
    /// NetAdapt); one-shot pruners ignore it.
    pub fn max_iterations(mut self, iters: usize) -> RunBuilder {
        self.max_iterations = Some(iters);
        self
    }

    /// Journal the run to `path` (DESIGN.md §15): the header and
    /// `config` records are written at [`build`](Self::build) time, then
    /// a fsync'd barrier is appended at the baseline and at every
    /// accepted iteration, so a crash loses at most the in-flight
    /// iteration. `config` pins what [`resume`](Self::resume) later
    /// rebuilds the run from.
    pub fn journal(mut self, path: impl Into<PathBuf>, config: JournalConfig) -> RunBuilder {
        self.journal_path = Some(path.into());
        self.journal_config = Some(config);
        self
    }

    /// Resume an interrupted journaled run (DESIGN.md §15): preloads
    /// every journaled tune-cache entry so the pre-crash iterations
    /// replay as pure cache hits, suppresses the already-journaled
    /// barriers, and appends new ones to the same journal — the full
    /// event stream comes out byte-identical to an uninterrupted run's.
    /// The caller must configure the builder to match the journal's own
    /// `config` record (read it via [`super::journal::read_config`]);
    /// a seed mismatch is rejected at [`build`](Self::build).
    pub fn resume(mut self, path: impl Into<PathBuf>) -> RunBuilder {
        self.resume_path = Some(path.into());
        self
    }

    /// Register an observer for the run's event stream (repeatable).
    pub fn observer(mut self, obs: Box<dyn RunObserver>) -> RunBuilder {
        self.observers.push(obs);
        self
    }

    /// Replace the default jitter-free [`ProxyOracle`].
    pub fn oracle(mut self, oracle: Box<dyn AccuracyOracle>) -> RunBuilder {
        self.oracle = Some(oracle);
        self
    }

    /// Build the model and measurement provider, loading the warm-start
    /// cache when its file exists. Fails on unknown device names,
    /// unreadable replay traces and corrupt cache files (loudly, rather
    /// than silently re-tuning from cold).
    pub fn build(self) -> Result<Run, String> {
        if let Some(e) = self.target_error {
            return Err(e);
        }
        let model = Model::build(self.kind, self.seed);
        let fitted = |spec: DeviceSpec| -> DeviceSpec {
            match self.calibration.as_ref().and_then(|t| t.get(spec.name)) {
                Some(cal) => calibration::apply(&spec, cal),
                None => spec,
            }
        };
        let base: Box<dyn Target> = match self.choice {
            TargetChoice::Spec(spec) => Box::new(AnalyticTarget::new(fitted(spec))),
            TargetChoice::Lut(spec) => {
                Box::new(LutTarget::for_model(fitted(spec), &model, &self.tune_opts, self.seed))
            }
            TargetChoice::Explicit(t) => t,
            // Either trace format replays (load_trace_target dispatches
            // on the document's format tag).
            TargetChoice::Replay(path) => Box::new(load_trace_target(&path)?),
            TargetChoice::Remote { spec, device, endpoints } => {
                let opts = RemoteOptions::default();
                let remote = if endpoints.is_empty() {
                    RemoteTarget::spawn(&device, self.workers, opts)?
                } else {
                    RemoteTarget::connect(&endpoints, opts)?
                };
                // The workers' Hello already proved they agree with each
                // other; now prove they measure the device the user named.
                if remote.spec().to_json().to_string() != spec.to_json().to_string() {
                    return Err(format!(
                        "remote pool measures '{}' but '{device}' resolves to '{}'",
                        remote.spec().name,
                        spec.name
                    ));
                }
                Box::new(remote)
            }
        };
        let target: Box<dyn Target> = if self.record_path.is_some() {
            Box::new(ReplayTarget::record(base))
        } else {
            base
        };
        if self.remote_trace_path.is_some() {
            match target.as_remote() {
                Some(remote) => remote.start_trace(),
                None => return Err("remote_trace set but target is not a remote pool".to_string()),
            }
        }
        let cache = match &self.cache_path {
            Some(p) if p.exists() => TuneCache::load(p, target.spec().name)?,
            _ => TuneCache::new(),
        };
        // Journal wiring (DESIGN.md §15): resume reopens an interrupted
        // journal and preloads its cache entries on top of any cache
        // file; a fresh journal pins the config for later resumes.
        let journal = match (&self.resume_path, &self.journal_path) {
            (Some(path), _) => {
                let (journal, state) = RunJournal::resume(path)?;
                if state.config.seed != self.seed {
                    return Err(format!(
                        "{}: journal was recorded with seed {}, builder configured with \
                         seed {} — resume must replay the original configuration",
                        path.display(),
                        state.config.seed,
                        self.seed
                    ));
                }
                state.preload(&cache).map_err(|e| format!("{}: {e}", path.display()))?;
                Some(journal)
            }
            (None, Some(path)) => {
                let config =
                    self.journal_config.as_ref().ok_or("journal path set without a config")?;
                Some(RunJournal::create(path, config)?)
            }
            (None, None) => None,
        };
        Ok(Run {
            model,
            target,
            trace_path: self.record_path,
            remote_trace_path: self.remote_trace_path,
            tune_opts: self.tune_opts,
            seed: self.seed,
            cache_path: self.cache_path,
            cache,
            accuracy_budget: self.accuracy_budget,
            max_iterations: self.max_iterations,
            observers: self.observers,
            oracle: self.oracle.unwrap_or_else(|| Box::new(ProxyOracle::new())),
            journal,
        })
    }
}

/// A fully wired run: execute any [`Pruner`] (repeatedly — the tune
/// cache carries over between executions, so comparing several
/// algorithms on one `Run` warm-starts the shared workloads exactly like
/// the legacy shared-session harnesses did).
pub struct Run {
    pub model: Model,
    target: Box<dyn Target>,
    /// Where to persist the recording target's trace after each execute.
    trace_path: Option<PathBuf>,
    /// Where to persist the remote pool's wire-level trace after each
    /// execute.
    remote_trace_path: Option<PathBuf>,
    tune_opts: TuneOptions,
    seed: u64,
    cache_path: Option<PathBuf>,
    cache: TuneCache,
    accuracy_budget: Option<f64>,
    max_iterations: Option<usize>,
    observers: Vec<Box<dyn RunObserver>>,
    oracle: Box<dyn AccuracyOracle>,
    /// Crash-safety journal (DESIGN.md §15) — attached to the context
    /// during execution, retrieved after to append `finished`.
    journal: Option<RunJournal>,
}

impl Run {
    /// Execute `pruner` against this run's wiring. Emits the
    /// [`crate::run::RunEvent::Finished`] event after the pruner returns,
    /// then persists the tune cache and measurement trace(s) when
    /// configured. A replay divergence (the structured [`Divergence`]
    /// unwind, CPV124) is caught here and returned as a plain `Err`, so
    /// the CLI reports it with exit 1 instead of a crash; every other
    /// panic keeps unwinding.
    pub fn execute(&mut self, pruner: &dyn Pruner) -> Result<PruneOutcome, String> {
        let cache = std::mem::take(&mut self.cache);
        let session =
            TuningSession::with_cache(self.target.as_ref(), self.tune_opts, self.seed, cache);
        let (outcome, events_emitted) = {
            let mut ctx = RunContext::new(
                &self.model,
                &session,
                &mut *self.oracle,
                self.observers.as_mut_slice(),
            );
            ctx.accuracy_budget = self.accuracy_budget;
            ctx.max_iterations = self.max_iterations;
            if let Some(j) = self.journal.take() {
                ctx.attach_journal(j);
            }
            let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                pruner.run(&mut ctx)
            }));
            let outcome = match caught {
                Ok(outcome) => outcome,
                Err(payload) => match payload.downcast::<Divergence>() {
                    Ok(d) => return Err(d.to_string()),
                    Err(other) => std::panic::resume_unwind(other),
                },
            };
            self.journal = ctx.detach_journal();
            (outcome, ctx.events_emitted())
        };
        let finished = outcome.finished_event();
        for obs in self.observers.iter_mut() {
            obs.on_event(&finished);
        }
        if let Some(j) = self.journal.as_mut() {
            // +1: the Finished event is dispatched here, outside the
            // context's emit() counter.
            j.record_finished(events_emitted + 1);
        }
        self.cache = session.cache;
        if let Some(path) = &self.cache_path {
            self.cache.save(path, self.target.spec().name)?;
        }
        if let Some(path) = &self.trace_path {
            match self.target.as_replay() {
                Some(trace) => trace.save(path)?,
                None => return Err("record_trace set but target is not recording".to_string()),
            }
        }
        if let Some(path) = &self.remote_trace_path {
            match self.target.as_remote() {
                Some(remote) => remote.save_trace(path)?,
                None => {
                    return Err("remote_trace set but target is not a remote pool".to_string())
                }
            }
        }
        // A broken observer (sink write error, registry save failure)
        // fails the run loudly — a truncated event log or unpersisted
        // frontier must not look like success.
        if let Some(e) = self.observers.iter().find_map(|o| o.failure()) {
            return Err(e);
        }
        // Same discipline for the journal: a run whose crash-safety net
        // silently failed to persist must not look recoverable.
        if let Some(e) = self.journal.as_ref().and_then(|j| j.error()) {
            return Err(format!("run journal: {e}"));
        }
        Ok(outcome)
    }

    /// The legacy "Original (TVM)" reference row plus its latency —
    /// measured on this run's session/cache, so a following
    /// [`execute`](Self::execute) reuses every tuned program.
    pub fn original_row(&mut self) -> (crate::baselines::Outcome, f64) {
        let cache = std::mem::take(&mut self.cache);
        let session =
            TuningSession::with_cache(self.target.as_ref(), self.tune_opts, self.seed, cache);
        let row = crate::baselines::original_row(&self.model, &session);
        self.cache = session.cache;
        row
    }

    /// The run's measurement provider.
    pub fn target(&self) -> &dyn Target {
        self.target.as_ref()
    }

    /// The tune cache in its current (post-execution) state.
    pub fn cache(&self) -> &TuneCache {
        &self.cache
    }

    /// Observers registered on this run (e.g. to inspect a
    /// [`crate::run::RegistryPublisher`] after executing).
    pub fn observers(&self) -> &[Box<dyn RunObserver>] {
        &self.observers
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::run::CPrune;

    #[test]
    fn unknown_device_fails_at_build() {
        let err = match RunBuilder::new(ModelKind::ResNet8Cifar).device("galaxy-s10").build() {
            Err(e) => e,
            Ok(_) => panic!("unknown device must fail"),
        };
        assert!(err.contains("galaxy-s10"), "{err}");
        // the diagnostic lists the registry's valid names
        assert!(err.contains("kryo385") && err.contains("mali-g72"), "{err}");
        // ...through target_name too
        let err = match RunBuilder::new(ModelKind::ResNet8Cifar)
            .target_name("lut:galaxy-s10")
            .build()
        {
            Err(e) => e,
            Ok(_) => panic!("unknown target must fail"),
        };
        assert!(err.contains("galaxy-s10") && err.contains("kryo585"), "{err}");
        // ...and the remote prefix resolves its bare name the same way
        let err = match RunBuilder::new(ModelKind::ResNet8Cifar)
            .target_name("remote:galaxy-s10@127.0.0.1:9999")
            .build()
        {
            Err(e) => e,
            Ok(_) => panic!("unknown remote device must fail"),
        };
        assert!(err.contains("galaxy-s10") && err.contains("kryo385"), "{err}");
    }

    #[test]
    fn remote_trace_without_a_remote_target_fails_at_build() {
        let err = match RunBuilder::new(ModelKind::ResNet8Cifar)
            .device("kryo385")
            .remote_trace("unused.json")
            .build()
        {
            Err(e) => e,
            Ok(_) => panic!("remote_trace needs a remote pool"),
        };
        assert!(err.contains("not a remote pool"), "{err}");
    }

    #[test]
    fn execute_carries_the_cache_across_runs() {
        let mut run = RunBuilder::new(ModelKind::ResNet8Cifar)
            .device("kryo385")
            .max_iterations(3)
            .build()
            .unwrap();
        let first = run.execute(&CPrune::default()).unwrap();
        assert!(first.programs_measured > 0);
        let second = run.execute(&CPrune::default()).unwrap();
        assert_eq!(second.programs_measured, 0, "second run should be all cache hits");
        assert_eq!(first.final_latency, second.final_latency);
        assert_eq!(first.channels, second.channels);
    }

    #[test]
    fn cache_file_round_trips_through_builder() {
        let path = std::env::temp_dir().join("cprune_run_builder_cache_test.json");
        let _ = std::fs::remove_file(&path);
        let mut cold = RunBuilder::new(ModelKind::ResNet8Cifar)
            .max_iterations(2)
            .cache(&path)
            .build()
            .unwrap();
        let a = cold.execute(&CPrune::default()).unwrap();
        assert!(a.programs_measured > 0);
        let mut warm = RunBuilder::new(ModelKind::ResNet8Cifar)
            .max_iterations(2)
            .cache(&path)
            .build()
            .unwrap();
        let b = warm.execute(&CPrune::default()).unwrap();
        assert_eq!(b.programs_measured, 0, "warm builder re-measured");
        assert_eq!(a.final_latency, b.final_latency);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn journaled_run_writes_and_finishes_a_journal() {
        let path = std::env::temp_dir().join("cprune_builder_journal_test.journal");
        let _ = std::fs::remove_file(&path);
        let config = JournalConfig {
            seed: 0,
            pruner: "cprune".to_string(),
            model: "resnet8-cifar".to_string(),
            device: "kryo385".to_string(),
            iters: 2,
            target_acc: None,
        };
        let mut run = RunBuilder::new(ModelKind::ResNet8Cifar)
            .device("kryo385")
            .max_iterations(2)
            .journal(&path, config)
            .build()
            .unwrap();
        run.execute(&CPrune::default()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("{\"format\":\"cprune-run-journal\""), "{text}");
        assert!(text.contains("\"record\":\"config\""), "{text}");
        assert!(text.contains("\"record\":\"baseline\""), "{text}");
        assert!(text.contains("\"record\":\"finished\""), "{text}");
        // a finished journal refuses resume
        let err = match RunBuilder::new(ModelKind::ResNet8Cifar)
            .device("kryo385")
            .max_iterations(2)
            .resume(&path)
            .build()
        {
            Err(e) => e,
            Ok(_) => panic!("resuming a finished journal must fail"),
        };
        assert!(err.contains("finished"), "{err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn target_name_resolves_providers() {
        let run = RunBuilder::new(ModelKind::ResNet8Cifar)
            .target_name("kryo585")
            .build()
            .unwrap();
        assert_eq!(run.target().spec().name, "Kryo 585 (Galaxy S20+)");
        // explicit provider injection
        let run = RunBuilder::new(ModelKind::ResNet8Cifar)
            .target(Box::new(AnalyticTarget::new(DeviceSpec::kryo280())))
            .build()
            .unwrap();
        assert_eq!(run.target().spec().name, "Kryo 280 (Galaxy S8)");
    }
}
