//! Fluent construction of pruning runs (DESIGN.md §9).
//!
//! [`RunBuilder`] owns the cross-cutting wiring that every experiment
//! harness, CLI path and bench used to hand-assemble: the model, the
//! target device, the tuning budget, the RNG seed, an optional warm-start
//! cache file, the accuracy budget, the oracle and the observers.
//!
//! ```no_run
//! use cprune::graph::model_zoo::ModelKind;
//! use cprune::run::{CPrune, RunBuilder};
//!
//! let mut run = RunBuilder::new(ModelKind::ResNet18Cifar)
//!     .device("kryo585")
//!     .seed(7)
//!     .cache("kryo585.cache.json")
//!     .build()
//!     .unwrap();
//! let outcome = run.execute(&CPrune::default()).unwrap();
//! println!("{:.2}x FPS", outcome.fps_increase_rate);
//! ```

use super::{PruneOutcome, Pruner, RunContext, RunObserver};
use crate::accuracy::{AccuracyOracle, ProxyOracle};
use crate::device::{DeviceSpec, Simulator};
use crate::graph::model_zoo::{Model, ModelKind};
use crate::tuner::{TuneCache, TuneOptions, TuningSession};
use std::path::PathBuf;

/// Builder for a [`Run`]. Defaults: Kryo 385, [`TuneOptions::quick`],
/// seed 0, a jitter-free [`ProxyOracle`], no cache, no observers.
pub struct RunBuilder {
    kind: ModelKind,
    device: DeviceSpec,
    device_error: Option<String>,
    tune_opts: TuneOptions,
    seed: u64,
    cache_path: Option<PathBuf>,
    accuracy_budget: Option<f64>,
    max_iterations: Option<usize>,
    observers: Vec<Box<dyn RunObserver>>,
    oracle: Option<Box<dyn AccuracyOracle>>,
}

impl RunBuilder {
    pub fn new(kind: ModelKind) -> RunBuilder {
        RunBuilder {
            kind,
            device: DeviceSpec::kryo385(),
            device_error: None,
            tune_opts: TuneOptions::quick(),
            seed: 0,
            cache_path: None,
            accuracy_budget: None,
            max_iterations: None,
            observers: Vec::new(),
            oracle: None,
        }
    }

    /// Target device by short name (`kryo280`, `kryo385`, `kryo585`,
    /// `mali-g72`, `rtx3080`); unknown names fail at [`build`](Self::build).
    pub fn device(mut self, name: &str) -> RunBuilder {
        match crate::exp::try_device_by_name(name) {
            Some(spec) => self.device = spec,
            None => {
                self.device_error = Some(format!(
                    "unknown device '{name}'. options: {}",
                    crate::exp::DEVICE_NAMES
                ))
            }
        }
        self
    }

    /// Target device by explicit spec.
    pub fn device_spec(mut self, spec: DeviceSpec) -> RunBuilder {
        self.device = spec;
        self
    }

    /// Tuning effort per task (defaults to [`TuneOptions::quick`]).
    pub fn tune_opts(mut self, opts: TuneOptions) -> RunBuilder {
        self.tune_opts = opts;
        self
    }

    /// Seed for model weights and every tuning/measurement RNG stream.
    pub fn seed(mut self, seed: u64) -> RunBuilder {
        self.seed = seed;
        self
    }

    /// Warm-start cache file: loaded (if present) at build time, saved
    /// back after every [`Run::execute`].
    pub fn cache(mut self, path: impl Into<PathBuf>) -> RunBuilder {
        self.cache_path = Some(path.into());
        self
    }

    /// Accuracy budget `a_g` override for the iterative searches
    /// (CPrune's `target_accuracy`, NetAdapt's short-accuracy floor).
    /// One-shot pruners (magnitude/FPGM/AMC/PQF) have no accuracy knob
    /// and ignore it.
    pub fn accuracy_budget(mut self, floor: f64) -> RunBuilder {
        self.accuracy_budget = Some(floor);
        self
    }

    /// Iteration-cap override for the iterative searches (CPrune,
    /// NetAdapt); one-shot pruners ignore it.
    pub fn max_iterations(mut self, iters: usize) -> RunBuilder {
        self.max_iterations = Some(iters);
        self
    }

    /// Register an observer for the run's event stream (repeatable).
    pub fn observer(mut self, obs: Box<dyn RunObserver>) -> RunBuilder {
        self.observers.push(obs);
        self
    }

    /// Replace the default jitter-free [`ProxyOracle`].
    pub fn oracle(mut self, oracle: Box<dyn AccuracyOracle>) -> RunBuilder {
        self.oracle = Some(oracle);
        self
    }

    /// Build the model and device simulator, loading the warm-start cache
    /// when its file exists. Fails on unknown device names and corrupt
    /// cache files (loudly, rather than silently re-tuning from cold).
    pub fn build(self) -> Result<Run, String> {
        if let Some(e) = self.device_error {
            return Err(e);
        }
        let cache = match &self.cache_path {
            Some(p) if p.exists() => TuneCache::load(p, self.device.name)?,
            _ => TuneCache::new(),
        };
        let model = Model::build(self.kind, self.seed);
        Ok(Run {
            model,
            sim: Simulator::new(self.device),
            tune_opts: self.tune_opts,
            seed: self.seed,
            cache_path: self.cache_path,
            cache,
            accuracy_budget: self.accuracy_budget,
            max_iterations: self.max_iterations,
            observers: self.observers,
            oracle: self.oracle.unwrap_or_else(|| Box::new(ProxyOracle::new())),
        })
    }
}

/// A fully wired run: execute any [`Pruner`] (repeatedly — the tune
/// cache carries over between executions, so comparing several
/// algorithms on one `Run` warm-starts the shared workloads exactly like
/// the legacy shared-session harnesses did).
pub struct Run {
    pub model: Model,
    pub sim: Simulator,
    tune_opts: TuneOptions,
    seed: u64,
    cache_path: Option<PathBuf>,
    cache: TuneCache,
    accuracy_budget: Option<f64>,
    max_iterations: Option<usize>,
    observers: Vec<Box<dyn RunObserver>>,
    oracle: Box<dyn AccuracyOracle>,
}

impl Run {
    /// Execute `pruner` against this run's wiring. Emits the
    /// [`crate::run::RunEvent::Finished`] event after the pruner returns,
    /// then persists the tune cache when a cache path was configured.
    pub fn execute(&mut self, pruner: &dyn Pruner) -> Result<PruneOutcome, String> {
        let cache = std::mem::take(&mut self.cache);
        let session = TuningSession::with_cache(&self.sim, self.tune_opts, self.seed, cache);
        let outcome = {
            let mut ctx = RunContext::new(
                &self.model,
                &session,
                &mut *self.oracle,
                self.observers.as_mut_slice(),
            );
            ctx.accuracy_budget = self.accuracy_budget;
            ctx.max_iterations = self.max_iterations;
            pruner.run(&mut ctx)
        };
        let finished = outcome.finished_event();
        for obs in self.observers.iter_mut() {
            obs.on_event(&finished);
        }
        self.cache = session.cache;
        if let Some(path) = &self.cache_path {
            self.cache.save(path, self.sim.spec.name)?;
        }
        // A broken observer (sink write error, registry save failure)
        // fails the run loudly — a truncated event log or unpersisted
        // frontier must not look like success.
        if let Some(e) = self.observers.iter().find_map(|o| o.failure()) {
            return Err(e);
        }
        Ok(outcome)
    }

    /// The legacy "Original (TVM)" reference row plus its latency —
    /// measured on this run's session/cache, so a following
    /// [`execute`](Self::execute) reuses every tuned program.
    pub fn original_row(&mut self) -> (crate::baselines::Outcome, f64) {
        let cache = std::mem::take(&mut self.cache);
        let session = TuningSession::with_cache(&self.sim, self.tune_opts, self.seed, cache);
        let row = crate::baselines::original_row(&self.model, &session);
        self.cache = session.cache;
        row
    }

    /// The tune cache in its current (post-execution) state.
    pub fn cache(&self) -> &TuneCache {
        &self.cache
    }

    /// Observers registered on this run (e.g. to inspect a
    /// [`crate::run::RegistryPublisher`] after executing).
    pub fn observers(&self) -> &[Box<dyn RunObserver>] {
        &self.observers
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::run::CPrune;

    #[test]
    fn unknown_device_fails_at_build() {
        let err = match RunBuilder::new(ModelKind::ResNet8Cifar).device("galaxy-s10").build() {
            Err(e) => e,
            Ok(_) => panic!("unknown device must fail"),
        };
        assert!(err.contains("galaxy-s10"), "{err}");
    }

    #[test]
    fn execute_carries_the_cache_across_runs() {
        let mut run = RunBuilder::new(ModelKind::ResNet8Cifar)
            .device("kryo385")
            .max_iterations(3)
            .build()
            .unwrap();
        let first = run.execute(&CPrune::default()).unwrap();
        assert!(first.programs_measured > 0);
        let second = run.execute(&CPrune::default()).unwrap();
        assert_eq!(second.programs_measured, 0, "second run should be all cache hits");
        assert_eq!(first.final_latency, second.final_latency);
        assert_eq!(first.channels, second.channels);
    }

    #[test]
    fn cache_file_round_trips_through_builder() {
        let path = std::env::temp_dir().join("cprune_run_builder_cache_test.json");
        let _ = std::fs::remove_file(&path);
        let mut cold = RunBuilder::new(ModelKind::ResNet8Cifar)
            .max_iterations(2)
            .cache(&path)
            .build()
            .unwrap();
        let a = cold.execute(&CPrune::default()).unwrap();
        assert!(a.programs_measured > 0);
        let mut warm = RunBuilder::new(ModelKind::ResNet8Cifar)
            .max_iterations(2)
            .cache(&path)
            .build()
            .unwrap();
        let b = warm.execute(&CPrune::default()).unwrap();
        assert_eq!(b.programs_measured, 0, "warm builder re-measured");
        assert_eq!(a.final_latency, b.final_latency);
        let _ = std::fs::remove_file(&path);
    }
}
