//! The typed event stream of a pruning run (DESIGN.md §9).
//!
//! Every [`crate::run::Pruner`] narrates its search through [`RunEvent`]s
//! delivered to the [`RunObserver`]s registered on the
//! [`crate::run::RunBuilder`]. Three observers ship with the crate:
//!
//! * [`JsonlSink`] — one JSON object per line, versioned schema
//!   ([`EVENTS_FORMAT`] v[`EVENTS_VERSION`], same header conventions as
//!   [`crate::tuner::TuneCache`] files);
//! * [`ProgressPrinter`] — human-readable live progress on stdout
//!   (what `cprune run` shows by default);
//! * [`RegistryPublisher`] — pushes every [`RunEvent::CheckpointEmitted`]
//!   frontier point into a [`crate::serve::Registry`], so a run's
//!   deployable checkpoints become servable the moment they are accepted.
//!
//! Events are borrowed (`&RunEvent`) by observers and never mutated, so
//! one event fan-outs to any number of sinks.

use crate::graph::ops::NodeId;
use crate::serve::{Checkpoint, Registry};
use crate::sparsity::Scheme;
use crate::util::json::Json;
use std::cell::RefCell;
use std::io::Write;
use std::path::PathBuf;
use std::rc::Rc;

/// Format tag written on the first line of a [`JsonlSink`] log.
pub const EVENTS_FORMAT: &str = "cprune-run-events";
/// Bump when the event schema changes; consumers reject other versions.
pub const EVENTS_VERSION: u64 = 1;

/// Why a measured candidate was not accepted.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// `l_m >= l_t`: the candidate missed the latency target (Alg. 1
    /// line 10) — the search escalates the pruning step and retries.
    LatencyGate,
    /// `a_s < α·a_p`: the short-term accuracy gate failed (line 11) —
    /// the task is banned for the rest of the run.
    AccuracyGate,
    /// `a_s ≤ a_g`: accepting would exhaust the user's accuracy budget —
    /// the run stops.
    AccuracyBudget,
}

impl RejectReason {
    /// Stable string used by the JSONL schema.
    pub fn as_str(&self) -> &'static str {
        match self {
            RejectReason::LatencyGate => "latency_gate",
            RejectReason::AccuracyGate => "accuracy_gate",
            RejectReason::AccuracyBudget => "accuracy_budget",
        }
    }
}

/// One typed event of a pruning run.
///
/// The JSONL serialization ([`RunEvent::to_json`]) is versioned
/// ([`EVENTS_VERSION`]) and pinned by a golden-file test; treat field
/// changes as schema bumps.
#[derive(Clone, Debug, PartialEq)]
pub enum RunEvent {
    /// The tuned-but-unpruned reference model was compiled and measured
    /// (Alg. 1 line 1) — the denominator of every FPS-increase rate.
    BaselineTuned { latency: f64, fps: f64 },
    /// A candidate model was compiled and measured against the current
    /// latency target.
    CandidateMeasured {
        iteration: usize,
        latency: f64,
        latency_target: f64,
        candidates_tried: usize,
        /// Sparsity scheme of the candidate move (DESIGN.md §16). `None`
        /// for pure channel pruners (schema-compatible with v1 streams:
        /// the field is omitted from the JSONL object when absent).
        scheme: Option<Scheme>,
    },
    /// A candidate passed both gates and became the new current model.
    IterationAccepted {
        iteration: usize,
        latency: f64,
        latency_target: f64,
        short_accuracy: f64,
        /// The gate value `α·a_p` the short-term accuracy was held to.
        accuracy_gate: f64,
        filters_removed: usize,
        /// Sparsity scheme of the accepted move (DESIGN.md §16); omitted
        /// from the JSONL object when `None`, keeping v1 streams stable.
        scheme: Option<Scheme>,
    },
    /// A candidate failed a gate. The accuracy fields are `None` for
    /// latency-gate rejections (the candidate is rejected before any
    /// short-term training happens).
    IterationRejected {
        iteration: usize,
        latency: f64,
        latency_target: f64,
        short_accuracy: Option<f64>,
        accuracy_gate: Option<f64>,
        reason: RejectReason,
    },
    /// A task (identified by its anchor conv) was banned from further
    /// pruning (Alg. 1 line 12).
    TaskBanned { conv: NodeId, reason: String },
    /// A deployable checkpoint was offered to the run's Pareto frontier.
    CheckpointEmitted { checkpoint: Checkpoint },
    /// The run finished; fields mirror the returned
    /// [`crate::run::PruneOutcome`].
    Finished {
        pruner: String,
        method: String,
        model: String,
        device: String,
        final_latency: f64,
        final_fps: f64,
        fps_increase_rate: f64,
        top1: f64,
        top5: f64,
        macs: u64,
        params: u64,
        iterations: usize,
        search_candidates: usize,
        pareto_points: usize,
    },
}

impl RunEvent {
    /// Stable kind tag used by the JSONL schema.
    pub fn kind(&self) -> &'static str {
        match self {
            RunEvent::BaselineTuned { .. } => "baseline_tuned",
            RunEvent::CandidateMeasured { .. } => "candidate_measured",
            RunEvent::IterationAccepted { .. } => "iteration_accepted",
            RunEvent::IterationRejected { .. } => "iteration_rejected",
            RunEvent::TaskBanned { .. } => "task_banned",
            RunEvent::CheckpointEmitted { .. } => "checkpoint_emitted",
            RunEvent::Finished { .. } => "finished",
        }
    }

    /// Serialize to the versioned JSONL object (`event` carries
    /// [`RunEvent::kind`]; keys come out sorted — the writer is
    /// byte-stable).
    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = vec![("event", Json::Str(self.kind().to_string()))];
        match self {
            RunEvent::BaselineTuned { latency, fps } => {
                pairs.push(("latency", Json::Num(*latency)));
                pairs.push(("fps", Json::Num(*fps)));
            }
            RunEvent::CandidateMeasured {
                iteration,
                latency,
                latency_target,
                candidates_tried,
                scheme,
            } => {
                pairs.push(("iteration", Json::Num(*iteration as f64)));
                pairs.push(("latency", Json::Num(*latency)));
                pairs.push(("latency_target", Json::Num(*latency_target)));
                pairs.push(("candidates_tried", Json::Num(*candidates_tried as f64)));
                if let Some(s) = scheme {
                    pairs.push(("scheme", Json::Str(s.name().to_string())));
                }
            }
            RunEvent::IterationAccepted {
                iteration,
                latency,
                latency_target,
                short_accuracy,
                accuracy_gate,
                filters_removed,
                scheme,
            } => {
                pairs.push(("iteration", Json::Num(*iteration as f64)));
                pairs.push(("latency", Json::Num(*latency)));
                pairs.push(("latency_target", Json::Num(*latency_target)));
                pairs.push(("short_accuracy", Json::Num(*short_accuracy)));
                pairs.push(("accuracy_gate", Json::Num(*accuracy_gate)));
                pairs.push(("filters_removed", Json::Num(*filters_removed as f64)));
                if let Some(s) = scheme {
                    pairs.push(("scheme", Json::Str(s.name().to_string())));
                }
            }
            RunEvent::IterationRejected {
                iteration,
                latency,
                latency_target,
                short_accuracy,
                accuracy_gate,
                reason,
            } => {
                pairs.push(("iteration", Json::Num(*iteration as f64)));
                pairs.push(("latency", Json::Num(*latency)));
                pairs.push(("latency_target", Json::Num(*latency_target)));
                pairs.push((
                    "short_accuracy",
                    short_accuracy.map(Json::Num).unwrap_or(Json::Null),
                ));
                pairs.push((
                    "accuracy_gate",
                    accuracy_gate.map(Json::Num).unwrap_or(Json::Null),
                ));
                pairs.push(("reason", Json::Str(reason.as_str().to_string())));
            }
            RunEvent::TaskBanned { conv, reason } => {
                pairs.push(("conv", Json::Num(*conv as f64)));
                pairs.push(("reason", Json::Str(reason.clone())));
            }
            RunEvent::CheckpointEmitted { checkpoint } => {
                pairs.push(("checkpoint", checkpoint.to_json()));
            }
            RunEvent::Finished {
                pruner,
                method,
                model,
                device,
                final_latency,
                final_fps,
                fps_increase_rate,
                top1,
                top5,
                macs,
                params,
                iterations,
                search_candidates,
                pareto_points,
            } => {
                pairs.push(("pruner", Json::Str(pruner.clone())));
                pairs.push(("method", Json::Str(method.clone())));
                pairs.push(("model", Json::Str(model.clone())));
                pairs.push(("device", Json::Str(device.clone())));
                pairs.push(("final_latency", Json::Num(*final_latency)));
                pairs.push(("final_fps", Json::Num(*final_fps)));
                pairs.push(("fps_increase_rate", Json::Num(*fps_increase_rate)));
                pairs.push(("top1", Json::Num(*top1)));
                pairs.push(("top5", Json::Num(*top5)));
                pairs.push(("macs", Json::Num(*macs as f64)));
                pairs.push(("params", Json::Num(*params as f64)));
                pairs.push(("iterations", Json::Num(*iterations as f64)));
                pairs.push(("search_candidates", Json::Num(*search_candidates as f64)));
                pairs.push(("pareto_points", Json::Num(*pareto_points as f64)));
            }
        }
        Json::obj(pairs)
    }

    /// The header object a [`JsonlSink`] writes as its first line.
    pub fn header_json() -> Json {
        Json::obj(vec![
            ("format", Json::Str(EVENTS_FORMAT.to_string())),
            ("version", Json::Num(EVENTS_VERSION as f64)),
        ])
    }
}

/// Receives every event of a run, in order.
pub trait RunObserver {
    fn on_event(&mut self, event: &RunEvent);

    /// A failure the observer hit while consuming events (e.g. a sink
    /// write error). Checked by [`crate::run::Run::execute`] after the
    /// [`RunEvent::Finished`] dispatch so broken sinks fail the run
    /// loudly instead of silently truncating their output.
    fn failure(&self) -> Option<String> {
        None
    }
}

/// Observer that discards everything (the default for the legacy
/// free-function entry points).
#[derive(Clone, Copy, Debug, Default)]
pub struct NullObserver;

impl RunObserver for NullObserver {
    fn on_event(&mut self, _event: &RunEvent) {}
}

/// JSONL sink: a `{format, version}` header line, then one JSON object
/// per event. Line-buffered with an explicit flush per event (plus on
/// drop), so a crash loses at most the in-flight line — the same
/// discipline as the run journal (DESIGN.md §15).
pub struct JsonlSink {
    out: Box<dyn Write>,
    /// First write error, if any (subsequent events are dropped).
    error: Option<String>,
}

impl JsonlSink {
    /// Create (truncate) `path` and write the schema header. Goes
    /// through [`crate::util::io::create_sink`] (fault site `events`).
    pub fn create(path: impl Into<PathBuf>) -> Result<JsonlSink, String> {
        let path = path.into();
        let f = crate::util::io::create_sink(&path, "events")?;
        Ok(Self::to_writer(Box::new(std::io::BufWriter::new(f))))
    }

    /// Wrap an arbitrary writer (tests use an in-memory buffer).
    pub fn to_writer(out: Box<dyn Write>) -> JsonlSink {
        let mut sink = JsonlSink { out, error: None };
        sink.write_line(&RunEvent::header_json());
        sink
    }

    fn write_line(&mut self, j: &Json) {
        if self.error.is_some() {
            return;
        }
        if let Err(e) = writeln!(self.out, "{j}") {
            self.error = Some(e.to_string());
        }
    }

    /// First write error hit by the sink, if any.
    pub fn error(&self) -> Option<&str> {
        self.error.as_deref()
    }
}

impl RunObserver for JsonlSink {
    fn on_event(&mut self, event: &RunEvent) {
        self.write_line(&event.to_json());
        // Flush per event, not just on Finished: after a crash the log
        // holds every delivered event except at most the in-flight line,
        // which is what lets --resume stitch a byte-identical stream.
        if let Err(e) = self.out.flush() {
            if self.error.is_none() {
                self.error = Some(e.to_string());
            }
        }
    }

    fn failure(&self) -> Option<String> {
        self.error.as_ref().map(|e| format!("events sink: {e}"))
    }
}

impl Drop for JsonlSink {
    fn drop(&mut self) {
        let _ = self.out.flush();
    }
}

/// Human-readable progress lines on stdout. Quiet by default about the
/// per-candidate noise; `verbose()` prints every measurement/rejection.
#[derive(Clone, Copy, Debug, Default)]
pub struct ProgressPrinter {
    verbose: bool,
}

impl ProgressPrinter {
    pub fn new() -> ProgressPrinter {
        ProgressPrinter::default()
    }

    /// Also print [`RunEvent::CandidateMeasured`] and latency-gate
    /// rejections (one line per compiled candidate).
    pub fn verbose(mut self) -> ProgressPrinter {
        self.verbose = true;
        self
    }
}

impl RunObserver for ProgressPrinter {
    fn on_event(&mut self, event: &RunEvent) {
        match event {
            RunEvent::BaselineTuned { latency, fps } => {
                println!("[run] baseline tuned: {:.2} ms ({fps:.1} FPS)", latency * 1e3);
            }
            RunEvent::CandidateMeasured {
                iteration,
                latency,
                latency_target,
                candidates_tried,
                ..
            } if self.verbose => {
                println!(
                    "[run] iter {iteration}: candidate #{candidates_tried} {:.2} ms (target {:.2} ms)",
                    latency * 1e3,
                    latency_target * 1e3
                );
            }
            RunEvent::IterationAccepted {
                iteration,
                latency,
                short_accuracy,
                accuracy_gate,
                filters_removed,
                ..
            } => {
                println!(
                    "[run] iter {iteration}: accepted {:.2} ms, a_s {:.4} (gate {:.4}), -{filters_removed} filters",
                    latency * 1e3,
                    short_accuracy,
                    accuracy_gate
                );
            }
            RunEvent::IterationRejected { iteration, reason, .. }
                if self.verbose || *reason != RejectReason::LatencyGate =>
            {
                println!("[run] iter {iteration}: rejected ({})", reason.as_str());
            }
            RunEvent::TaskBanned { conv, reason } => {
                println!("[run] banned task anchored at conv {conv} ({reason})");
            }
            RunEvent::Finished {
                method,
                fps_increase_rate,
                top1,
                iterations,
                pareto_points,
                ..
            } => {
                println!(
                    "[run] finished: {method} {fps_increase_rate:.2}x FPS, top-1 {:.2}%, {iterations} iterations, {pareto_points}-point frontier",
                    top1 * 100.0
                );
            }
            _ => {}
        }
    }
}

/// Auto-publisher: folds every emitted checkpoint into a shared
/// [`Registry`] under a fixed `(model, device)` key, and (optionally)
/// saves the registry to disk when the run finishes.
///
/// Because [`crate::serve::ParetoSet`] insertion is order-independent
/// (dominated points are evicted whichever side arrives first), the
/// frontier this publisher accumulates is exactly the run's final
/// `PruneOutcome::pareto`.
pub struct RegistryPublisher {
    registry: Rc<RefCell<Registry>>,
    model: String,
    device: String,
    save_path: Option<PathBuf>,
    /// First save error, if any (reported via [`RunObserver::failure`]).
    error: Option<String>,
}

impl RegistryPublisher {
    /// Publish into a fresh registry owned by this publisher.
    pub fn new(model: &str, device: &str) -> RegistryPublisher {
        Self::shared(Rc::new(RefCell::new(Registry::new())), model, device)
    }

    /// Publish into a registry shared with the caller (and possibly with
    /// other runs' publishers).
    pub fn shared(
        registry: Rc<RefCell<Registry>>,
        model: &str,
        device: &str,
    ) -> RegistryPublisher {
        RegistryPublisher {
            registry,
            model: model.to_string(),
            device: device.to_string(),
            save_path: None,
            error: None,
        }
    }

    /// Also save the registry to `path` when the run finishes.
    pub fn saving_to(mut self, path: impl Into<PathBuf>) -> RegistryPublisher {
        self.save_path = Some(path.into());
        self
    }

    /// Handle to the registry being published into.
    pub fn registry(&self) -> Rc<RefCell<Registry>> {
        self.registry.clone()
    }
}

impl RunObserver for RegistryPublisher {
    fn on_event(&mut self, event: &RunEvent) {
        match event {
            RunEvent::CheckpointEmitted { checkpoint } => {
                let mut one = crate::serve::ParetoSet::new();
                one.insert(checkpoint.clone());
                self.registry
                    .borrow_mut()
                    .publish(&self.model, &self.device, &one);
            }
            RunEvent::Finished { .. } => {
                if let Some(path) = &self.save_path {
                    if let Err(e) = self.registry.borrow().save(path) {
                        if self.error.is_none() {
                            self.error = Some(format!("registry publisher: {e}"));
                        }
                    }
                }
            }
            _ => {}
        }
    }

    fn failure(&self) -> Option<String> {
        self.error.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;
    use std::collections::BTreeMap;

    #[test]
    fn every_event_serializes_to_parseable_json_with_kind_tag() {
        let events = vec![
            RunEvent::BaselineTuned { latency: 0.25, fps: 4.0 },
            RunEvent::CandidateMeasured {
                iteration: 1,
                latency: 0.125,
                latency_target: 0.25,
                candidates_tried: 3,
                scheme: None,
            },
            RunEvent::IterationAccepted {
                iteration: 1,
                latency: 0.125,
                latency_target: 0.25,
                short_accuracy: 0.5,
                accuracy_gate: 0.25,
                filters_removed: 8,
                scheme: None,
            },
            RunEvent::IterationRejected {
                iteration: 2,
                latency: 0.5,
                latency_target: 0.25,
                short_accuracy: None,
                accuracy_gate: None,
                reason: RejectReason::LatencyGate,
            },
            RunEvent::TaskBanned { conv: 7, reason: "accuracy_gate".into() },
            RunEvent::CheckpointEmitted {
                checkpoint: Checkpoint {
                    iteration: 1,
                    latency: 0.125,
                    accuracy: 0.5,
                    channels: BTreeMap::new(),
                    schemes: BTreeMap::new(),
                },
            },
            RunEvent::Finished {
                pruner: "cprune".into(),
                method: "CPrune".into(),
                model: "m".into(),
                device: "d".into(),
                final_latency: 0.125,
                final_fps: 8.0,
                fps_increase_rate: 2.0,
                top1: 0.5,
                top5: 0.75,
                macs: 100,
                params: 10,
                iterations: 1,
                search_candidates: 3,
                pareto_points: 2,
            },
        ];
        for ev in &events {
            let text = ev.to_json().to_string();
            let back = json::parse(&text).expect("event line must parse");
            assert_eq!(
                back.get("event").and_then(Json::as_str),
                Some(ev.kind()),
                "missing kind tag in {text}"
            );
        }
    }

    #[test]
    fn scheme_field_is_omitted_when_absent_and_named_when_present() {
        let without = RunEvent::CandidateMeasured {
            iteration: 1,
            latency: 0.125,
            latency_target: 0.25,
            candidates_tried: 1,
            scheme: None,
        }
        .to_json()
        .to_string();
        assert!(!without.contains("scheme"), "None must serialize v1-identically: {without}");
        let with = RunEvent::IterationAccepted {
            iteration: 1,
            latency: 0.125,
            latency_target: 0.25,
            short_accuracy: 0.5,
            accuracy_gate: 0.25,
            filters_removed: 0,
            scheme: Some(Scheme::Pattern),
        }
        .to_json();
        assert_eq!(with.get("scheme").and_then(Json::as_str), Some("pattern"));
    }

    #[test]
    fn jsonl_sink_writes_header_then_events() {
        use std::io::Cursor;
        // Write into a shared buffer we can inspect after the sink drops.
        struct Shared(Rc<RefCell<Cursor<Vec<u8>>>>);
        impl Write for Shared {
            fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
                self.0.borrow_mut().write(buf)
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let buf = Rc::new(RefCell::new(Cursor::new(Vec::new())));
        {
            let mut sink = JsonlSink::to_writer(Box::new(Shared(buf.clone())));
            sink.on_event(&RunEvent::BaselineTuned { latency: 0.5, fps: 2.0 });
            assert!(sink.error().is_none());
        }
        let bytes = buf.borrow().get_ref().clone();
        let text = String::from_utf8(bytes).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let header = json::parse(lines[0]).unwrap();
        assert_eq!(header.get("format").and_then(Json::as_str), Some(EVENTS_FORMAT));
        assert_eq!(header.get("version").and_then(Json::as_usize), Some(1));
        let ev = json::parse(lines[1]).unwrap();
        assert_eq!(ev.get("event").and_then(Json::as_str), Some("baseline_tuned"));
    }

    #[test]
    fn registry_publisher_accumulates_checkpoints() {
        let mut publisher = RegistryPublisher::new("m", "d");
        let reg = publisher.registry();
        for (it, lat, acc) in [(0, 0.5, 0.75), (1, 0.25, 0.5), (2, 0.125, 0.25)] {
            publisher.on_event(&RunEvent::CheckpointEmitted {
                checkpoint: Checkpoint {
                    iteration: it,
                    latency: lat,
                    accuracy: acc,
                    channels: BTreeMap::new(),
                    schemes: BTreeMap::new(),
                },
            });
        }
        let reg = reg.borrow();
        let set = reg.get("m", "d").expect("frontier published");
        assert_eq!(set.len(), 3); // mutually non-dominated chain
    }
}
