//! [`Pruner`] implementations: CPrune plus the five baselines, and the
//! string registry the CLI and experiment harnesses select them from.
//!
//! Each implementation delegates to the algorithm's home module
//! (`pruner::cprune`, `baselines::*`) — the legacy free functions there
//! are thin shims over these trait impls, so both spellings produce
//! byte-identical results for a fixed seed.

use super::{finalize, PruneOutcome, Pruner, RunContext, RunEvent, SearchEnd};
use crate::accuracy::Criterion;
use crate::baselines::amc::{amc_search, AmcConfig};
use crate::baselines::netadapt::{netadapt_run, NetAdaptConfig};
use crate::baselines::pqf::{latency_multiplier, TOP1_DROP, TOP5_DROP};
use crate::baselines::uniform_prune;
use crate::compiler;
use crate::graph::prune::PruneState;
use crate::graph::stats;
use crate::pruner::{cprune_run, CPruneConfig, CPruneResult};
use crate::serve::{Checkpoint, ParetoSet};
use std::collections::HashMap;

/// Space-separated registry names (CLI help text).
pub const PRUNER_NAMES: &str = "cprune magnitude fpgm netadapt amc pqf pattern block scheme-select";

/// Look up a pruner by registry name, with its paper-default
/// configuration. `None` for unknown names.
pub fn pruner_by_name(name: &str) -> Option<Box<dyn Pruner>> {
    match name {
        "cprune" => Some(Box::new(CPrune::default())),
        "magnitude" | "l1" => Some(Box::new(Magnitude::at(0.3))),
        "fpgm" => Some(Box::new(Fpgm::at(0.25))),
        "netadapt" => Some(Box::new(NetAdapt::default())),
        "amc" => Some(Box::new(Amc::default())),
        "pqf" => Some(Box::new(Pqf)),
        "pattern" => Some(Box::new(crate::sparsity::PatternPruner)),
        "block" => Some(Box::new(crate::sparsity::BlockPruner)),
        "scheme-select" => Some(Box::new(crate::sparsity::SchemeSelect::default())),
        _ => None,
    }
}

/// The paper's contribution behind the uniform interface.
///
/// `cfg.tune_opts` and `cfg.seed` only matter to sessions built by the
/// legacy [`crate::pruner::cprune`] entry point — under a
/// [`crate::run::Run`] the session's own options and seed govern tuning.
/// The context's `accuracy_budget` / `max_iterations` overrides (set via
/// [`crate::run::RunBuilder`]) take precedence over the config's.
pub struct CPrune {
    pub cfg: CPruneConfig,
    label: String,
}

impl Default for CPrune {
    fn default() -> Self {
        Self::with_cfg(CPruneConfig::default())
    }
}

impl CPrune {
    pub fn with_cfg(cfg: CPruneConfig) -> CPrune {
        CPrune { cfg, label: "CPrune".to_string() }
    }

    /// Override the display label (Table 2's ablation rows).
    pub fn with_label(mut self, label: &str) -> CPrune {
        self.label = label.to_string();
        self
    }

    fn effective_cfg(&self, ctx: &RunContext) -> CPruneConfig {
        let mut cfg = self.cfg.clone();
        if let Some(a) = ctx.accuracy_budget {
            cfg.target_accuracy = a;
        }
        if let Some(n) = ctx.max_iterations {
            cfg.max_iterations = n;
        }
        cfg
    }

    /// Run CPrune and keep the full [`CPruneResult`] (final graph and
    /// task table included) — for callers like the Fig. 8 transfer
    /// matrix that need more than the uniform [`PruneOutcome`].
    pub fn run_full(&self, ctx: &mut RunContext) -> CPruneResult {
        let cfg = self.effective_cfg(ctx);
        cprune_run(ctx, &cfg)
    }
}

impl Pruner for CPrune {
    fn name(&self) -> &str {
        "cprune"
    }

    fn run(&self, ctx: &mut RunContext) -> PruneOutcome {
        let r = self.run_full(ctx);
        let (flops, params) = stats::flops_params(&r.final_graph);
        PruneOutcome {
            pruner: self.name().to_string(),
            method: self.label.clone(),
            model: ctx.model.kind.name().to_string(),
            device: ctx.device().to_string(),
            baseline_latency: r.baseline.latency(),
            final_latency: r.final_latency,
            final_fps: r.final_fps,
            fps_increase_rate: r.fps_increase_rate,
            macs: flops / 2,
            params,
            top1: r.final_top1,
            top5: r.final_top5,
            channels: r.final_state.cout,
            pareto: r.pareto,
            iterations: r.iterations,
            search_candidates: r.candidates_tried,
            main_step_seconds: r.main_step_seconds,
            programs_measured: r.programs_measured,
        }
    }
}

/// One-shot uniform ℓ1 pruning at a fixed ratio.
pub struct Magnitude {
    pub ratio: f64,
}

impl Magnitude {
    pub fn at(ratio: f64) -> Magnitude {
        Magnitude { ratio }
    }
}

impl Pruner for Magnitude {
    fn name(&self) -> &str {
        "magnitude"
    }

    fn run(&self, ctx: &mut RunContext) -> PruneOutcome {
        let state = uniform_prune(ctx.model, self.ratio, Criterion::L1Norm, 0);
        finalize(
            ctx,
            SearchEnd {
                pruner: "magnitude",
                method: format!("Magnitude(l1)@{:.0e}", self.ratio),
                state,
                criterion: Criterion::L1Norm,
                search_candidates: 0,
                main_step_seconds: 0.0,
                iterations: Vec::new(),
                checkpoints: Vec::new(),
            },
        )
    }
}

/// One-shot geometric-median pruning (He et al., CVPR 2019).
pub struct Fpgm {
    pub ratio: f64,
}

impl Fpgm {
    pub fn at(ratio: f64) -> Fpgm {
        Fpgm { ratio }
    }
}

impl Pruner for Fpgm {
    fn name(&self) -> &str {
        "fpgm"
    }

    fn run(&self, ctx: &mut RunContext) -> PruneOutcome {
        let state = uniform_prune(ctx.model, self.ratio, Criterion::GeomMedian, 0);
        finalize(
            ctx,
            SearchEnd {
                pruner: "fpgm",
                method: "FPGM+TVM".to_string(),
                state,
                criterion: Criterion::GeomMedian,
                search_candidates: 0,
                main_step_seconds: 0.0,
                iterations: Vec::new(),
                checkpoints: Vec::new(),
            },
        )
    }
}

/// NetAdapt's per-layer empirical measurement loop (Yang et al., 2018).
/// The context's `max_iterations` / `accuracy_budget` overrides map onto
/// the config's iteration cap and short-accuracy floor.
#[derive(Default)]
pub struct NetAdapt {
    pub cfg: NetAdaptConfig,
}

impl NetAdapt {
    pub fn with(cfg: NetAdaptConfig) -> NetAdapt {
        NetAdapt { cfg }
    }
}

impl Pruner for NetAdapt {
    fn name(&self) -> &str {
        "netadapt"
    }

    fn run(&self, ctx: &mut RunContext) -> PruneOutcome {
        let mut cfg = self.cfg.clone();
        if let Some(n) = ctx.max_iterations {
            cfg.max_iterations = n;
        }
        if let Some(a) = ctx.accuracy_budget {
            cfg.min_short_accuracy = a;
        }
        netadapt_run(ctx, &cfg)
    }
}

/// Greedy AMC (He et al., 2018): per-layer sparsity from a grid under a
/// MACs budget, maximizing the same accuracy-with-FLOPs-bonus reward.
#[derive(Default)]
pub struct Amc {
    pub cfg: AmcConfig,
}

impl Amc {
    pub fn with(cfg: AmcConfig) -> Amc {
        Amc { cfg }
    }
}

impl Pruner for Amc {
    fn name(&self) -> &str {
        "amc"
    }

    fn run(&self, ctx: &mut RunContext) -> PruneOutcome {
        let state = amc_search(ctx, &self.cfg);
        finalize(
            ctx,
            SearchEnd {
                pruner: "amc",
                method: "AMC+TVM".to_string(),
                state,
                criterion: Criterion::L1Norm,
                search_candidates: 0,
                main_step_seconds: 0.0,
                iterations: Vec::new(),
                checkpoints: Vec::new(),
            },
        )
    }
}

/// PQF (Martinez et al., 2021): non-structural permute-quantize-finetune.
/// The network shape is unchanged; the outcome models the device-kind
/// dependent decode overhead and the paper's reported accuracy cost.
pub struct Pqf;

impl Pruner for Pqf {
    fn name(&self) -> &str {
        "pqf"
    }

    fn run(&self, ctx: &mut RunContext) -> PruneOutcome {
        let model = ctx.model;
        let session = ctx.session;
        let baseline_latency = ctx.baseline_latency();
        let compiled = compiler::compile_tuned(&model.graph, session, &HashMap::new());
        let latency = compiled.latency() * latency_multiplier(session.spec().kind);
        let (flops, params) = stats::flops_params(&model.graph);
        let (b1, b5) = model.kind.base_accuracy();
        let top1 = (b1 - TOP1_DROP).max(0.0);
        let top5 = (b5 - TOP5_DROP).max(0.0);
        let channels = PruneState::full(model).cout;
        let checkpoint = Checkpoint {
            iteration: 1,
            latency,
            accuracy: top1,
            channels: channels.clone(),
            schemes: std::collections::BTreeMap::new(),
        };
        ctx.emit(&RunEvent::CheckpointEmitted { checkpoint: checkpoint.clone() });
        let mut pareto = ParetoSet::new();
        pareto.insert(checkpoint);
        PruneOutcome {
            pruner: self.name().to_string(),
            method: "PQF+TVM".to_string(),
            model: model.kind.name().to_string(),
            device: ctx.device().to_string(),
            baseline_latency,
            final_latency: latency,
            final_fps: 1.0 / latency,
            fps_increase_rate: baseline_latency / latency,
            macs: flops / 2, // structure unchanged (tables print "-")
            params,
            top1,
            top5,
            channels,
            pareto,
            iterations: Vec::new(),
            search_candidates: 0,
            main_step_seconds: 0.0,
            programs_measured: session.measured_count(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::model_zoo::ModelKind;
    use crate::run::RunBuilder;

    #[test]
    fn registry_resolves_every_documented_name() {
        for name in PRUNER_NAMES.split_whitespace() {
            let p = pruner_by_name(name).unwrap_or_else(|| panic!("missing pruner {name}"));
            assert_eq!(p.name(), name);
        }
        assert!(pruner_by_name("dropout").is_none());
    }

    #[test]
    fn every_pruner_runs_under_the_same_builder_wiring() {
        let mut run = RunBuilder::new(ModelKind::ResNet8Cifar)
            .device("kryo385")
            .seed(1)
            .max_iterations(3)
            .build()
            .unwrap();
        for name in PRUNER_NAMES.split_whitespace() {
            let pruner = pruner_by_name(name).unwrap();
            let out = run.execute(pruner.as_ref()).unwrap();
            assert_eq!(out.pruner, name);
            assert!(out.final_fps > 0.0 && out.final_fps.is_finite(), "{name}");
            assert!(out.top1 > 0.0 && out.top1 <= 1.0, "{name}");
            assert!(!out.pareto.is_empty(), "{name}: frontier must be servable");
            assert!(out.baseline_latency > 0.0, "{name}");
            // every frontier point carries a deployable channel map
            for c in out.pareto.points() {
                assert!(c.instantiate(&run.model).is_ok(), "{name}");
            }
        }
    }

    #[test]
    fn one_shot_baselines_emit_a_one_point_frontier() {
        let mut run = RunBuilder::new(ModelKind::Vgg16Cifar)
            .device("kryo385")
            .seed(2)
            .build()
            .unwrap();
        for pruner in [&Magnitude::at(0.3) as &dyn Pruner, &Fpgm::at(0.25), &Pqf] {
            let out = run.execute(pruner).unwrap();
            assert_eq!(out.pareto.len(), 1, "{}", pruner.name());
            assert!(out.iterations.is_empty());
            let point = out.pareto.fastest().unwrap();
            assert_eq!(point.latency, out.final_latency, "{}", pruner.name());
            assert_eq!(point.accuracy, out.top1, "{}", pruner.name());
        }
    }
}
