//! Analytic accuracy proxy, calibrated against the paper's tables.
//!
//! Model: each pruned layer contributes an accuracy drop that is
//! super-linear in its pruned fraction and weighted by a layer
//! *sensitivity* (early layers and narrow layers hurt more — the standard
//! empirical profile from Li et al. / NetAdapt's per-layer sweeps).
//! Short-term fine-tuning recovers part of the drop; final training
//! recovers more. A criterion factor separates ℓ1 / geometric-median /
//! random selection quality.
//!
//! Calibration anchors (paper Tables 1–2):
//! * ResNet-18/ImageNet: −35 % MACs → −1.46 pp top-1 (final)
//! * MobileNetV2/ImageNet: −15 % MACs → −1.55 pp (mobile nets are fragile)
//! * ResNet-18/CIFAR-10:  −71 % MACs → −0.63 pp (CIFAR is tolerant)
//!
//! The proxy is *deterministic*: experiment harnesses can replay runs
//! bit-identically. An optional seeded jitter models epoch-to-epoch spread
//! where an experiment needs it (Fig. 1's scatter).

use super::{AccuracyOracle, Criterion, PruneSummary, TrainPhase};
use crate::graph::model_zoo::ModelKind;
use crate::util::rng::Rng;

/// Analytic oracle. Cheap enough to call thousands of times per search.
#[derive(Clone, Debug)]
pub struct ProxyOracle {
    /// Optional jitter sigma (fraction of a percentage point); 0 = off.
    pub jitter_sigma: f64,
    rng: Rng,
}

impl ProxyOracle {
    pub fn new() -> ProxyOracle {
        ProxyOracle { jitter_sigma: 0.0, rng: Rng::new(0) }
    }

    pub fn with_jitter(sigma: f64, seed: u64) -> ProxyOracle {
        ProxyOracle { jitter_sigma: sigma, rng: Rng::new(seed) }
    }

    /// Dataset/architecture fragility: drop (in accuracy fraction) per unit
    /// of sensitivity-weighted pruned mass, for FINAL training.
    fn fragility(model: ModelKind) -> f64 {
        match model {
            // ImageNet models: small prunes cost real accuracy.
            ModelKind::ResNet18ImageNet => 0.070,
            ModelKind::ResNet34ImageNet => 0.060, // deeper → more redundancy
            ModelKind::MobileNetV1ImageNet => 0.150,
            ModelKind::MobileNetV2ImageNet => 0.230, // already-compact net
            ModelKind::MnasNet10ImageNet => 0.200,   // NAS-optimized, fragile
            // CIFAR models tolerate heavy pruning.
            ModelKind::Vgg16Cifar => 0.012,
            ModelKind::ResNet18Cifar => 0.011,
            ModelKind::ResNet8Cifar => 0.045,
        }
    }

    /// Short-term training recovers less than final training.
    fn phase_factor(phase: TrainPhase) -> f64 {
        match phase {
            TrainPhase::Short => 2.2,
            TrainPhase::Final => 1.0,
        }
    }

    fn criterion_factor(c: Criterion) -> f64 {
        match c {
            Criterion::L1Norm => 1.0,
            Criterion::GeomMedian => 0.96, // marginally better selection
            Criterion::Random => 1.6,
        }
    }

    /// Sensitivity weight of one layer: early layers (small depth) and
    /// narrow layers are more sensitive.
    fn layer_sensitivity(depth: f64, original_channels: usize) -> f64 {
        let positional = 1.35 - 0.7 * depth; // 1.35 at input → 0.65 at output
        let width = (64.0 / original_channels.max(8) as f64).powf(0.25);
        positional * width
    }

    /// Deterministic top-1 estimate.
    pub fn top1_det(&self, summary: &PruneSummary, phase: TrainPhase) -> f64 {
        let (base, _) = summary.model.base_accuracy();
        if summary.layers.is_empty() || summary.is_identity() {
            return base;
        }
        // Mean sensitivity-weighted pruned mass over the listed layers
        // (unpruned layers contribute 0, so broad light pruning and narrow
        // heavy pruning trade off super-linearly via the 1.5 exponent).
        let mut weighted = 0.0;
        for l in &summary.layers {
            let frac = 1.0 - l.remaining_channels as f64 / l.original_channels.max(1) as f64;
            let w = Self::layer_sensitivity(l.depth, l.original_channels);
            weighted += w * frac.powf(1.5);
        }
        let mass = weighted / summary.layers.len() as f64;
        let drop = Self::fragility(summary.model)
            * Self::phase_factor(phase)
            * Self::criterion_factor(summary.criterion)
            * mass;
        (base - drop).clamp(0.05, 1.0)
    }
}

impl Default for ProxyOracle {
    fn default() -> Self {
        Self::new()
    }
}

impl AccuracyOracle for ProxyOracle {
    fn top1(&mut self, summary: &PruneSummary, phase: TrainPhase) -> f64 {
        let det = self.top1_det(summary, phase);
        if self.jitter_sigma > 0.0 {
            (det + self.rng.normal() as f64 * self.jitter_sigma).clamp(0.05, 1.0)
        } else {
            det
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accuracy::LayerPrune;

    fn summary(model: ModelKind, layers: Vec<(usize, usize, usize, f64)>) -> PruneSummary {
        PruneSummary {
            model,
            layers: layers
                .into_iter()
                .map(|(conv, orig, rem, depth)| LayerPrune {
                    conv,
                    original_channels: orig,
                    remaining_channels: rem,
                    depth,
                })
                .collect(),
            criterion: Criterion::L1Norm,
        }
    }

    #[test]
    fn unpruned_returns_base() {
        let mut o = ProxyOracle::new();
        let s = PruneSummary::unpruned(ModelKind::ResNet18ImageNet);
        assert_eq!(o.top1(&s, TrainPhase::Final), 0.6976);
        assert!((o.top5(&s, TrainPhase::Final) - 0.8908).abs() < 1e-9);
    }

    #[test]
    fn more_pruning_means_lower_accuracy() {
        let mut o = ProxyOracle::new();
        let light = summary(ModelKind::ResNet18ImageNet, vec![(1, 512, 480, 0.9)]);
        let heavy = summary(ModelKind::ResNet18ImageNet, vec![(1, 512, 128, 0.9)]);
        assert!(
            o.top1(&light, TrainPhase::Final) > o.top1(&heavy, TrainPhase::Final)
        );
    }

    #[test]
    fn short_term_is_worse_than_final() {
        let mut o = ProxyOracle::new();
        let s = summary(ModelKind::ResNet18ImageNet, vec![(1, 512, 256, 0.5)]);
        assert!(o.top1(&s, TrainPhase::Short) < o.top1(&s, TrainPhase::Final));
    }

    #[test]
    fn early_layers_hurt_more() {
        let mut o = ProxyOracle::new();
        let early = summary(ModelKind::ResNet18ImageNet, vec![(1, 128, 64, 0.1)]);
        let late = summary(ModelKind::ResNet18ImageNet, vec![(9, 128, 64, 0.9)]);
        assert!(o.top1(&early, TrainPhase::Final) < o.top1(&late, TrainPhase::Final));
    }

    #[test]
    fn random_criterion_is_worse_than_l1() {
        let mut o = ProxyOracle::new();
        let mut s = summary(ModelKind::Vgg16Cifar, vec![(1, 256, 128, 0.5)]);
        let l1 = o.top1(&s, TrainPhase::Final);
        s.criterion = Criterion::Random;
        let rand = o.top1(&s, TrainPhase::Final);
        assert!(rand < l1);
    }

    #[test]
    fn calibration_resnet18_imagenet() {
        // ~35% uniform pruning of mid layers → final drop ≈ 1–2 pp.
        let mut o = ProxyOracle::new();
        let layers: Vec<(usize, usize, usize, f64)> = (0..16)
            .map(|i| (i, 256usize, 166usize, (i as f64 + 1.0) / 16.0))
            .collect();
        let s = summary(ModelKind::ResNet18ImageNet, layers);
        let drop = 0.6976 - o.top1(&s, TrainPhase::Final);
        assert!(
            (0.008..0.030).contains(&drop),
            "ResNet-18 final drop {drop} out of paper ballpark (0.0146)"
        );
    }

    #[test]
    fn calibration_resnet18_cifar_tolerates_heavy_pruning() {
        // ~70% pruning → final drop below ~1.5 pp (paper: 0.63 pp).
        let mut o = ProxyOracle::new();
        let layers: Vec<(usize, usize, usize, f64)> = (0..16)
            .map(|i| (i, 256usize, 77usize, (i as f64 + 1.0) / 16.0))
            .collect();
        let s = summary(ModelKind::ResNet18Cifar, layers);
        let drop = 0.9437 - o.top1(&s, TrainPhase::Final);
        assert!(
            (0.001..0.015).contains(&drop),
            "CIFAR final drop {drop} out of ballpark (0.0063)"
        );
    }

    #[test]
    fn jitter_is_seeded() {
        let s = summary(ModelKind::Vgg16Cifar, vec![(1, 256, 128, 0.5)]);
        let mut a = ProxyOracle::with_jitter(0.002, 42);
        let mut b = ProxyOracle::with_jitter(0.002, 42);
        assert_eq!(a.top1(&s, TrainPhase::Short), b.top1(&s, TrainPhase::Short));
    }

    #[test]
    fn top5_drops_less_than_top1() {
        let mut o = ProxyOracle::new();
        let s = summary(ModelKind::ResNet18ImageNet, vec![(1, 512, 200, 0.4)]);
        let (b1, b5) = ModelKind::ResNet18ImageNet.base_accuracy();
        let d1 = b1 - o.top1(&s, TrainPhase::Final);
        let d5 = b5 - o.top5(&s, TrainPhase::Final);
        assert!(d5 < d1);
    }
}
