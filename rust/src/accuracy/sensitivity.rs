//! Per-layer pruning-sensitivity scan (NetAdapt-style analysis).
//!
//! For each prunable conv, sweep pruned fractions and query the oracle's
//! short-term accuracy — producing the sensitivity curves hardware-aware
//! pruners consult and the paper's supplementary α/β discussion relies on.
//! Also exposes `latency_sensitivity`: the FPS side of the same sweep,
//! which is where CPrune's compiler-awareness shows up (accuracy-equal
//! layers can have wildly different latency payoffs).

use super::{AccuracyOracle, Criterion, TrainPhase};
use crate::compiler;
use crate::graph::model_zoo::Model;
use crate::graph::prune::{apply, PruneState};
use crate::pruner::summarize;
use crate::tuner::TuningSession;
use std::collections::HashMap;

/// One (layer, fraction) sample of the scan.
#[derive(Clone, Debug)]
pub struct SensitivityPoint {
    pub conv: usize,
    pub conv_name: String,
    pub pruned_fraction: f64,
    pub short_top1: f64,
    /// Latency of the whole model with only this layer pruned (seconds).
    pub latency: f64,
}

/// Sweep `fractions` per prunable layer; returns all sample points.
pub fn scan(
    model: &Model,
    session: &TuningSession,
    oracle: &mut dyn AccuracyOracle,
    fractions: &[f64],
) -> Vec<SensitivityPoint> {
    let mut out = Vec::new();
    for &conv in &model.prunable {
        let full = PruneState::full(model);
        let total = full.remaining(conv);
        for &frac in fractions {
            let mut st = full.clone();
            let k = ((total as f64 * frac).round() as usize).min(total.saturating_sub(2));
            st.shrink(conv, k);
            let acc = oracle.top1(
                &summarize(model, &st, Criterion::L1Norm),
                TrainPhase::Short,
            );
            let graph = apply(&model.graph, &st.cout).expect("valid pruned graph"); // cprune-lint: allow(CPL005, reason="pruners emit only valid states")
            let lat = compiler::compile_tuned(&graph, session, &HashMap::new()).latency();
            out.push(SensitivityPoint {
                conv,
                conv_name: model.graph.node(conv).name.clone(),
                pruned_fraction: frac,
                short_top1: acc,
                latency: lat,
            });
        }
    }
    out
}

/// Rank layers by "efficiency frontier": latency saved per accuracy lost
/// at the given fraction. High values = good pruning targets — compare
/// with CPrune's impact ordering, which needs no per-layer sweep at all.
pub fn frontier(
    points: &[SensitivityPoint],
    base_latency: f64,
    base_accuracy: f64,
    fraction: f64,
) -> Vec<(String, f64)> {
    let mut rows: Vec<(String, f64)> = points
        .iter()
        .filter(|p| (p.pruned_fraction - fraction).abs() < 1e-9)
        .map(|p| {
            let saved = (base_latency - p.latency).max(0.0);
            let lost = (base_accuracy - p.short_top1).max(1e-6);
            (p.conv_name.clone(), saved / lost)
        })
        .collect();
    rows.sort_by(|a, b| b.1.total_cmp(&a.1));
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accuracy::ProxyOracle;
    use crate::device::{DeviceSpec, Simulator};
    use crate::graph::model_zoo::ModelKind;
    use crate::tuner::TuneOptions;

    #[test]
    fn scan_produces_monotone_layer_curves() {
        let model = Model::build(ModelKind::ResNet8Cifar, 0);
        let sim = Simulator::new(DeviceSpec::kryo385());
        let session = TuningSession::new(&sim, TuneOptions::quick(), 1);
        let mut oracle = ProxyOracle::new();
        let pts = scan(&model, &session, &mut oracle, &[0.25, 0.5]);
        assert_eq!(pts.len(), model.prunable.len() * 2);
        // within a layer, deeper pruning → lower accuracy & lower latency
        for &conv in &model.prunable {
            let l: Vec<&SensitivityPoint> = pts.iter().filter(|p| p.conv == conv).collect();
            assert!(l[0].short_top1 >= l[1].short_top1);
        }
    }

    #[test]
    fn frontier_ranks_all_layers() {
        let model = Model::build(ModelKind::ResNet8Cifar, 0);
        let sim = Simulator::new(DeviceSpec::kryo385());
        let session = TuningSession::new(&sim, TuneOptions::quick(), 1);
        let mut oracle = ProxyOracle::new();
        let base = compiler::compile_tuned(&model.graph, &session, &HashMap::new()).latency();
        let pts = scan(&model, &session, &mut oracle, &[0.5]);
        let f = frontier(&pts, base, model.kind.base_accuracy().0, 0.5);
        assert_eq!(f.len(), model.prunable.len());
        // sorted descending
        for w in f.windows(2) {
            assert!(w[0].1 >= w[1].1);
        }
    }
}
