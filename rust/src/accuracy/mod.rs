//! Accuracy oracles: what "short-term train and measure a_s" (Alg. 1
//! line 11) and "final long-term training" (line 17) return.
//!
//! Two implementations:
//! * [`proxy::ProxyOracle`] — analytic model for the ImageNet/CIFAR-scale
//!   workloads (no ImageNet in this environment; DESIGN.md §2), calibrated
//!   so the paper's (FLOPs-reduction → accuracy-drop) pairs hold;
//! * `train::TrainedOracle` (in `crate::train`) — *real* training of the
//!   CIFAR-scale masked CNN through the AOT-compiled PJRT train step,
//!   used by the end-to-end example.

pub mod proxy;
pub mod sensitivity;

pub use proxy::ProxyOracle;

use crate::graph::model_zoo::ModelKind;

/// Which filter-selection criterion produced the prune sets (affects
/// accuracy quality; §3.5 uses ℓ1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Criterion {
    /// Smallest ℓ1-norm filters first (CPrune, NetAdapt, AMC, magnitude).
    L1Norm,
    /// Distance-to-geometric-median (FPGM).
    GeomMedian,
    /// Random selection (Fig. 1's random pruned variants).
    Random,
}

/// Training budget of an accuracy query.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrainPhase {
    /// Short-term fine-tune (5 epochs CIFAR / 1 epoch ImageNet).
    Short,
    /// Full training at the end of the search (100 / 20 epochs).
    Final,
}

/// Per-layer pruning description handed to an oracle.
#[derive(Clone, Debug)]
pub struct LayerPrune {
    /// Conv node id in the *original* graph.
    pub conv: usize,
    pub original_channels: usize,
    pub remaining_channels: usize,
    /// Relative depth of the layer in (0, 1]: position / #convs.
    pub depth: f64,
}

/// Whole-model pruning summary.
#[derive(Clone, Debug)]
pub struct PruneSummary {
    pub model: ModelKind,
    pub layers: Vec<LayerPrune>,
    pub criterion: Criterion,
}

impl PruneSummary {
    pub fn unpruned(model: ModelKind) -> PruneSummary {
        PruneSummary { model, layers: Vec::new(), criterion: Criterion::L1Norm }
    }

    /// True when no layer lost any channel.
    pub fn is_identity(&self) -> bool {
        self.layers
            .iter()
            .all(|l| l.remaining_channels == l.original_channels)
    }
}

/// The oracle interface Algorithm 1 calls.
pub trait AccuracyOracle {
    /// Top-1 accuracy (fraction) after the given training phase.
    fn top1(&mut self, summary: &PruneSummary, phase: TrainPhase) -> f64;

    /// Top-5 accuracy; default mapping mirrors the paper's tables where
    /// top-5 drops ≈ 0.6 × top-1 drops.
    fn top5(&mut self, summary: &PruneSummary, phase: TrainPhase) -> f64 {
        let (b1, b5) = summary.model.base_accuracy();
        let drop1 = (b1 - self.top1(summary, phase)).max(0.0);
        (b5 - 0.6 * drop1).clamp(0.0, 1.0)
    }
}
