//! PQF — Permute, Quantize, Fine-tune (Martinez et al., CVPR 2021).
//!
//! A non-structural compressor: weights are permuted and vector-quantized
//! into codebooks. The network *shape* is unchanged, so the compiler's
//! task structure is identical to the original — the runtime effect is a
//! per-op decode overhead that mobile CPUs hide poorly (Table 1: 0.99× on
//! Kryo 385) while GPUs benefit from the smaller weight traffic (1.54× on
//! Mali-G72). We model exactly that: a device-kind-dependent latency
//! multiplier on the tuned original, plus the paper's reported accuracy
//! cost (codebook quantization hurts more than structured ℓ1 pruning).

use super::Outcome;
use crate::accuracy::ProxyOracle;
use crate::device::{DeviceKind, Target};
use crate::graph::model_zoo::Model;
use crate::run::{Pqf, Pruner, RunContext};
use crate::tuner::TuningSession;

/// Latency multiplier of PQF-compressed execution vs. f32 on this device
/// kind (from the paper's Table 1 measurements).
pub fn latency_multiplier(kind: DeviceKind) -> f64 {
    match kind {
        DeviceKind::Cpu => 1.01,  // decode overhead ≈ cancels savings
        DeviceKind::Gpu => 1.0 / 1.54, // weight-traffic-bound: big win
    }
}

/// Accuracy cost of 8x codebook compression (paper: 69.76 → 66.74 top-1).
pub const TOP1_DROP: f64 = 0.0302;
pub const TOP5_DROP: f64 = 0.0192;

/// Legacy free-function entry point — a thin shim over the [`Pqf`]
/// pruner (DESIGN.md §9). `target` is unused (the device kind comes from
/// the session's own target) and kept for signature stability; PQF needs
/// no oracle, so the shim supplies a throwaway one.
pub fn pqf(
    model: &Model,
    session: &TuningSession,
    target: &dyn Target,
    baseline_latency: f64,
) -> Outcome {
    let _ = target;
    let mut oracle = ProxyOracle::new();
    let mut ctx =
        RunContext::standalone(model, session, &mut oracle).with_baseline(baseline_latency);
    Pqf.run(&mut ctx).to_outcome()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{DeviceSpec, Simulator};
    use crate::graph::model_zoo::ModelKind;
    use crate::tuner::TuneOptions;

    #[test]
    fn pqf_helps_gpu_not_cpu() {
        let m = Model::build(ModelKind::ResNet8Cifar, 0);
        let cpu = Simulator::new(DeviceSpec::kryo385());
        let gpu = Simulator::new(DeviceSpec::mali_g72());
        let cpu_sess = TuningSession::new(&cpu, TuneOptions::quick(), 1);
        let gpu_sess = TuningSession::new(&gpu, TuneOptions::quick(), 1);
        let base_cpu = crate::baselines::original_row(&m, &cpu_sess).1;
        let base_gpu = crate::baselines::original_row(&m, &gpu_sess).1;
        let on_cpu = pqf(&m, &cpu_sess, &cpu, base_cpu);
        let on_gpu = pqf(&m, &gpu_sess, &gpu, base_gpu);
        assert!(on_cpu.fps_increase_rate < 1.05);
        assert!(on_gpu.fps_increase_rate > 1.3);
        // accuracy cost applies regardless of device
        let (b1, _) = m.kind.base_accuracy();
        assert!(on_cpu.top1 < b1);
    }
}
