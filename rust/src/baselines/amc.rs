//! AMC — AutoML for Model Compression (He et al., ECCV 2018), simplified.
//!
//! The original trains a DDPG agent to emit per-layer sparsities under a
//! FLOPs budget. Training an RL agent adds nothing to the comparison our
//! substrate isolates (search policy over the same latency/accuracy
//! signals), so we use the deterministic greedy equivalent: walk layers in
//! order, pick each layer's sparsity from a grid to maximize the same
//! reward AMC optimizes (accuracy with a log-FLOPs bonus) subject to the
//! remaining budget. Documented as a substitution in DESIGN.md §2.

use super::Outcome;
use crate::accuracy::{AccuracyOracle, Criterion, TrainPhase};
use crate::graph::model_zoo::Model;
use crate::graph::prune::{apply, PruneState};
use crate::graph::stats;
use crate::graph::weights::Weights;
use crate::run::{Amc, Pruner, RunContext};
use crate::tuner::TuningSession;

/// AMC configuration.
#[derive(Clone, Debug)]
pub struct AmcConfig {
    /// Target fraction of original MACs to keep (e.g. 0.8).
    pub macs_budget: f64,
    /// Sparsity grid searched per layer.
    pub grid: Vec<f64>,
}

impl Default for AmcConfig {
    fn default() -> Self {
        AmcConfig {
            macs_budget: 0.8,
            grid: vec![0.0, 0.1, 0.2, 0.3, 0.4, 0.5],
        }
    }
}

/// The greedy layer-wise search: walk layers in order, pick each layer's
/// sparsity from the grid to maximize the AMC reward under the remaining
/// MACs budget. Pure policy — latency never enters (which is exactly why
/// Table 1 shows AMC trailing the hardware-aware searches on FPS).
pub(crate) fn amc_search(ctx: &mut RunContext, cfg: &AmcConfig) -> PruneState {
    let model = ctx.model;
    let (orig_flops, _) = stats::flops_params(&model.graph);
    let target_flops = orig_flops as f64 * cfg.macs_budget;

    let mut state = PruneState::full(model);
    let mut weights = model.weights.clone();

    for &conv in &model.prunable {
        // Choose the sparsity that maximizes reward while heading toward
        // the budget: reward = short_acc − λ·max(0, flops_excess_ratio).
        let mut best: Option<(f64, PruneState, Weights)> = None;
        for &sp in &cfg.grid {
            let mut cand_state = state.clone();
            let mut cand_weights = weights.clone();
            let total = cand_state.remaining(conv);
            let k = ((total as f64 * sp).round() as usize).min(total.saturating_sub(2));
            if k > 0 {
                let idx = Weights::lowest_k(&cand_weights.l1_norms(conv), k);
                cand_weights.remove_filters(conv, &idx);
                cand_state.shrink(conv, k);
            }
            let Ok(g) = apply(&model.graph, &cand_state.cout) else { continue };
            let (flops, _) = stats::flops_params(&g);
            let cand_summary = crate::pruner::summarize(model, &cand_state, Criterion::L1Norm);
            let acc = ctx.oracle.top1(&cand_summary, TrainPhase::Short);
            let excess = (flops as f64 / target_flops - 1.0).max(0.0);
            let reward = acc - 2.0 * excess;
            if best.as_ref().map(|(r, ..)| reward > *r).unwrap_or(true) {
                best = Some((reward, cand_state, cand_weights));
            }
        }
        if let Some((_, s, w)) = best {
            state = s;
            weights = w;
        }
    }
    state
}

/// Legacy free-function entry point — a thin shim over the [`Amc`]
/// pruner (DESIGN.md §9).
pub fn amc(
    model: &Model,
    session: &TuningSession,
    oracle: &mut dyn AccuracyOracle,
    cfg: &AmcConfig,
    baseline_latency: f64,
) -> Outcome {
    let mut ctx = RunContext::standalone(model, session, oracle).with_baseline(baseline_latency);
    Amc::with(cfg.clone()).run(&mut ctx).to_outcome()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accuracy::ProxyOracle;
    use crate::baselines::original_row;
    use crate::device::{DeviceSpec, Simulator};
    use crate::graph::model_zoo::ModelKind;
    use crate::tuner::TuneOptions;

    #[test]
    fn amc_approaches_flops_budget() {
        let m = Model::build(ModelKind::ResNet8Cifar, 0);
        let sim = Simulator::new(DeviceSpec::kryo385());
        let session = TuningSession::new(&sim, TuneOptions::quick(), 3);
        let mut oracle = ProxyOracle::new();
        let (orig, base_lat) = original_row(&m, &session);
        let cfg = AmcConfig { macs_budget: 0.75, ..Default::default() };
        let out = amc(&m, &session, &mut oracle, &cfg, base_lat);
        let kept = out.macs as f64 / orig.macs as f64;
        assert!(kept < 1.0, "AMC pruned nothing");
        assert!(kept > 0.4, "AMC over-pruned: kept {kept}");
        assert!(out.fps >= orig.fps);
    }
}
