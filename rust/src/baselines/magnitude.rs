//! Uniform-ratio magnitude (ℓ1) pruning — and the random-pruning variant
//! used to generate Fig. 1's twenty pruned VGG-16 models.

use super::Outcome;
use crate::accuracy::AccuracyOracle;
use crate::graph::model_zoo::Model;
use crate::graph::prune::PruneState;
use crate::run::{Magnitude, Pruner, RunContext};
use crate::tuner::TuningSession;
use crate::util::rng::Rng;

/// One-shot ℓ1 pruning at a fixed ratio, then final fine-tune. Thin shim
/// over the [`Magnitude`] pruner (DESIGN.md §9).
pub fn magnitude_prune(
    model: &Model,
    ratio: f64,
    session: &TuningSession,
    oracle: &mut dyn AccuracyOracle,
    baseline_latency: f64,
) -> Outcome {
    let mut ctx = RunContext::standalone(model, session, oracle).with_baseline(baseline_latency);
    Magnitude::at(ratio).run(&mut ctx).to_outcome()
}

/// A randomly pruned model variant (Fig. 1). The paper's 20 variants all
/// sit in a narrow accuracy band (92.8–93.1 %), i.e. they compress by a
/// *similar overall amount* but distribute the pruning differently across
/// layers — which is exactly what decouples pre- and post-compilation
/// speed (per-layer channel structure, not total FLOPs, decides how well
/// each layer tunes). We reproduce that: mean pruned fraction ≈
/// `max_ratio/2` per variant, with high per-layer variance.
pub fn random_variant(model: &Model, max_ratio: f64, seed: u64) -> PruneState {
    let mut rng = Rng::new(seed);
    let mut state = PruneState::full(model);
    let mut weights = model.weights.clone();
    let mean_ratio = max_ratio / 2.0;
    for &conv in &model.prunable {
        let total = state.remaining(conv);
        // lognormal spread around the common mean, clamped
        let ratio = (mean_ratio * rng.lognormal(0.7)).clamp(0.0, 0.8);
        let k = ((total as f64 * ratio).round() as usize).min(total.saturating_sub(2));
        if k == 0 {
            continue;
        }
        let mut all: Vec<usize> = (0..total).collect();
        rng.shuffle(&mut all);
        let mut sel = all[..k].to_vec();
        sel.sort_unstable();
        weights.remove_filters(conv, &sel);
        state.shrink(conv, k);
    }
    state
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accuracy::ProxyOracle;
    use crate::baselines::original_row;
    use crate::device::{DeviceSpec, Simulator};
    use crate::graph::model_zoo::ModelKind;
    use crate::tuner::TuneOptions;

    #[test]
    fn magnitude_prune_speeds_up_and_drops_accuracy() {
        let m = Model::build(ModelKind::Vgg16Cifar, 0);
        let sim = Simulator::new(DeviceSpec::kryo385());
        let session = TuningSession::new(&sim, TuneOptions::quick(), 1);
        let mut oracle = ProxyOracle::new();
        let (orig, base_lat) = original_row(&m, &session);
        let out = magnitude_prune(&m, 0.3, &session, &mut oracle, base_lat);
        assert!(out.fps > orig.fps);
        assert!(out.top1 < orig.top1);
        assert!(out.macs < orig.macs);
    }

    #[test]
    fn random_variants_differ_by_seed() {
        let m = Model::build(ModelKind::Vgg16Cifar, 0);
        let a = random_variant(&m, 0.5, 1);
        let b = random_variant(&m, 0.5, 2);
        assert_ne!(a, b);
        // all channels at least 2
        for (_, &c) in &a.cout {
            assert!(c >= 2);
        }
    }
}
