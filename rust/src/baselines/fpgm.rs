//! FPGM — Filter Pruning via Geometric Median (He et al., CVPR 2019).
//!
//! Prunes, per layer, the filters closest to the layer's geometric median
//! (most redundant), at a uniform ratio. Model-only: no hardware feedback,
//! which is exactly why the paper's Table 1 shows it trailing CPrune on
//! FPS despite decent accuracy.

use super::Outcome;
use crate::accuracy::AccuracyOracle;
use crate::graph::model_zoo::Model;
use crate::run::{Fpgm, Pruner, RunContext};
use crate::tuner::TuningSession;

/// The ratio FPGM's paper uses for ResNets (40% of filters scored, ~30%
/// pruned effective); we expose it as a parameter. Thin shim over the
/// [`Fpgm`] pruner (DESIGN.md §9).
pub fn fpgm_prune(
    model: &Model,
    ratio: f64,
    session: &TuningSession,
    oracle: &mut dyn AccuracyOracle,
    baseline_latency: f64,
) -> Outcome {
    let mut ctx = RunContext::standalone(model, session, oracle).with_baseline(baseline_latency);
    Fpgm::at(ratio).run(&mut ctx).to_outcome()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accuracy::ProxyOracle;
    use crate::baselines::{magnitude::magnitude_prune, original_row};
    use crate::device::{DeviceSpec, Simulator};
    use crate::graph::model_zoo::ModelKind;
    use crate::tuner::TuneOptions;

    #[test]
    fn fpgm_beats_magnitude_on_accuracy_at_same_ratio() {
        let m = Model::build(ModelKind::Vgg16Cifar, 0);
        let sim = Simulator::new(DeviceSpec::kryo385());
        let session = TuningSession::new(&sim, TuneOptions::quick(), 1);
        let mut oracle = ProxyOracle::new();
        let (_, base_lat) = original_row(&m, &session);
        let f = fpgm_prune(&m, 0.3, &session, &mut oracle, base_lat);
        let g = magnitude_prune(&m, 0.3, &session, &mut oracle, base_lat);
        assert!(f.top1 >= g.top1, "fpgm {} < magnitude {}", f.top1, g.top1);
        assert!(f.fps > 0.0 && f.macs > 0);
    }
}
