//! NetAdapt (Yang et al., ECCV 2018): platform-aware pruning by direct
//! per-layer measurement — the paper's strongest hardware-aware baseline
//! and the exhaustive-search reference of Fig. 11.
//!
//! Each iteration: for *every* prunable layer independently, find the
//! smallest filter count whose measured latency meets the iteration's
//! reduction budget; short-term fine-tune each candidate; keep the most
//! accurate one. This measures #layers candidates per iteration — the
//! cost CPrune's selective, impact-ordered search avoids (~90 % less,
//! Fig. 11).
//!
//! Faithful to the paper's Alg. with two environment substitutions: the
//! latency lookup is our device simulator via tuned compilation (NetAdapt
//! uses lookup tables of measured layer latencies), and short-term
//! accuracy is the shared oracle.
//!
//! The search itself lives in [`netadapt_run`], narrated through the
//! run layer's event stream; [`netadapt`] is the legacy free-function
//! shim over it (DESIGN.md §9).

use super::Outcome;
use crate::accuracy::{AccuracyOracle, Criterion, TrainPhase};
use crate::device::Target;
use crate::graph::model_zoo::Model;
use crate::graph::prune::{apply, PruneState};
use crate::graph::weights::Weights;
use crate::pruner::IterationLog;
use crate::run::{finalize, PruneOutcome, RejectReason, RunContext, RunEvent, SearchEnd};
use crate::serve::Checkpoint;
use crate::tuner::TuningSession;
use crate::{compiler, pruner};
use std::collections::HashMap;
use std::time::Instant;

/// NetAdapt configuration.
#[derive(Clone, Debug)]
pub struct NetAdaptConfig {
    /// Fraction of current latency to remove per iteration (the paper's
    /// resource reduction schedule), e.g. 0.03.
    pub step_ratio: f64,
    /// Stop when latency ≤ this fraction of the original (budget).
    pub target_latency_ratio: f64,
    /// Accuracy floor for accepting a candidate (short-term).
    pub min_short_accuracy: f64,
    pub max_iterations: usize,
}

impl Default for NetAdaptConfig {
    fn default() -> Self {
        NetAdaptConfig {
            step_ratio: 0.04,
            target_latency_ratio: 0.6,
            min_short_accuracy: 0.0,
            max_iterations: 40,
        }
    }
}

/// Result, including the search-cost counters Fig. 11 plots.
#[derive(Clone, Debug)]
pub struct NetAdaptResult {
    pub outcome: Outcome,
    pub state: PruneState,
    pub iterations: usize,
    pub candidates_tried: usize,
}

/// The observed search: runs against the context's model/session/oracle,
/// emitting the typed event stream (every measured layer candidate, the
/// accepted iteration, the deployable checkpoint). The outcome's
/// `channels` map carries the final pruning state.
pub(crate) fn netadapt_run(ctx: &mut RunContext, cfg: &NetAdaptConfig) -> PruneOutcome {
    let t0 = Instant::now();
    let model = ctx.model;
    let session = ctx.session;
    let base_latency = ctx.baseline_latency();
    let target = base_latency * cfg.target_latency_ratio;

    let mut state = PruneState::full(model);
    let mut weights = model.weights.clone();
    let mut cur_latency = base_latency;
    let mut candidates = 0usize;
    let mut iterations: Vec<IterationLog> = Vec::new();
    let mut checkpoints: Vec<Checkpoint> = Vec::new();

    // The unpruned model anchors the slow/accurate end of the frontier,
    // exactly like CPrune's iteration-0 checkpoint.
    let initial_summary = pruner::summarize(model, &state, Criterion::L1Norm);
    let base_accuracy = ctx.oracle.top1(&initial_summary, TrainPhase::Short);
    let baseline_checkpoint = Checkpoint {
        iteration: 0,
        latency: base_latency,
        accuracy: base_accuracy,
        channels: state.cout.clone(),
        schemes: std::collections::BTreeMap::new(),
    };
    ctx.emit(&RunEvent::CheckpointEmitted { checkpoint: baseline_checkpoint.clone() });
    checkpoints.push(baseline_checkpoint);

    for _ in 0..cfg.max_iterations {
        if cur_latency <= target {
            break;
        }
        let iter_no = iterations.len() + 1;
        let budget = cur_latency * (1.0 - cfg.step_ratio);

        // Exhaustive per-layer candidate generation:
        // (acc, state, weights, latency, conv, filters_removed).
        let mut best: Option<(f64, PruneState, Weights, f64, usize, usize)> = None;
        for &conv in &model.prunable {
            let remaining = state.remaining(conv);
            if remaining <= 2 {
                continue;
            }
            // Grow the pruned count until the measured latency meets the
            // budget (the paper walks its layer lookup table the same way).
            let mut k = (remaining / 8).max(1);
            let mut found: Option<(PruneState, Weights, f64, usize)> = None;
            while k < remaining - 1 {
                let mut cand_state = state.clone();
                let mut cand_weights = weights.clone();
                let idx = Weights::lowest_k(&cand_weights.l1_norms(conv), k);
                cand_weights.remove_filters(conv, &idx);
                cand_state.shrink(conv, k);
                let Ok(g) = apply(&model.graph, &cand_state.cout) else { break };
                let lat = compiler::compile_tuned(&g, session, &HashMap::new()).latency();
                candidates += 1;
                ctx.emit(&RunEvent::CandidateMeasured {
                    iteration: iter_no,
                    latency: lat,
                    latency_target: budget,
                    candidates_tried: candidates,
                    scheme: None,
                });
                if lat <= budget {
                    found = Some((cand_state, cand_weights, lat, k));
                    break;
                }
                ctx.emit(&RunEvent::IterationRejected {
                    iteration: iter_no,
                    latency: lat,
                    latency_target: budget,
                    short_accuracy: None,
                    accuracy_gate: None,
                    reason: RejectReason::LatencyGate,
                });
                k = (k * 2).min(remaining - 1);
            }
            if let Some((cand_state, cand_weights, lat, k)) = found {
                let cand_summary = pruner::summarize(model, &cand_state, Criterion::L1Norm);
                let acc = ctx.oracle.top1(&cand_summary, TrainPhase::Short);
                if acc < cfg.min_short_accuracy {
                    ctx.emit(&RunEvent::IterationRejected {
                        iteration: iter_no,
                        latency: lat,
                        latency_target: budget,
                        short_accuracy: Some(acc),
                        accuracy_gate: Some(cfg.min_short_accuracy),
                        reason: RejectReason::AccuracyGate,
                    });
                } else if best.as_ref().map(|(a, ..)| acc > *a).unwrap_or(true) {
                    best = Some((acc, cand_state, cand_weights, lat, conv, k));
                }
            }
        }

        match best {
            Some((acc, s, w, lat, conv, k)) => {
                state = s;
                weights = w;
                cur_latency = lat;
                ctx.emit(&RunEvent::IterationAccepted {
                    iteration: iter_no,
                    latency: lat,
                    latency_target: budget,
                    short_accuracy: acc,
                    accuracy_gate: cfg.min_short_accuracy,
                    filters_removed: k,
                    scheme: None,
                });
                let checkpoint = Checkpoint {
                    iteration: iter_no,
                    latency: lat,
                    accuracy: acc,
                    channels: state.cout.clone(),
                    schemes: std::collections::BTreeMap::new(),
                };
                ctx.emit(&RunEvent::CheckpointEmitted { checkpoint: checkpoint.clone() });
                checkpoints.push(checkpoint);
                iterations.push(IterationLog {
                    iteration: iter_no,
                    pruned_convs: vec![conv],
                    filters_removed: k,
                    latency: lat,
                    fps_rate: base_latency / lat,
                    short_accuracy: acc,
                    candidates_tried: candidates,
                });
            }
            None => break, // no layer can meet the budget
        }
    }

    finalize(
        ctx,
        SearchEnd {
            pruner: "netadapt",
            method: "NetAdapt+TVM".to_string(),
            state,
            criterion: Criterion::L1Norm,
            search_candidates: candidates,
            main_step_seconds: t0.elapsed().as_secs_f64(),
            iterations,
            checkpoints,
        },
    )
}

/// Legacy free-function entry point — a thin shim over [`netadapt_run`]
/// with no observers. `target` is unused (measurement goes through the
/// session's tuned compile path) and kept for signature stability.
pub fn netadapt(
    model: &Model,
    session: &TuningSession,
    target: &dyn Target,
    oracle: &mut dyn AccuracyOracle,
    cfg: &NetAdaptConfig,
) -> NetAdaptResult {
    let _ = target;
    let mut ctx = RunContext::standalone(model, session, oracle);
    let po = netadapt_run(&mut ctx, cfg);
    NetAdaptResult {
        iterations: po.iterations.len(),
        candidates_tried: po.search_candidates,
        state: PruneState { cout: po.channels.clone() },
        outcome: po.to_outcome(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accuracy::ProxyOracle;
    use crate::device::{DeviceSpec, Simulator};
    use crate::graph::model_zoo::ModelKind;
    use crate::tuner::TuneOptions;

    #[test]
    fn netadapt_reaches_latency_target_with_many_candidates() {
        let m = Model::build(ModelKind::ResNet8Cifar, 0);
        let sim = Simulator::new(DeviceSpec::kryo385());
        let session = TuningSession::new(&sim, TuneOptions::quick(), 2);
        let mut oracle = ProxyOracle::new();
        let cfg = NetAdaptConfig {
            target_latency_ratio: 0.8,
            max_iterations: 10,
            ..Default::default()
        };
        let r = netadapt(&m, &session, &sim, &mut oracle, &cfg);
        assert!(r.outcome.fps_increase_rate > 1.0);
        assert!(r.iterations >= 1);
        // exhaustive: candidates ≥ iterations (one per layer per iter at least)
        assert!(r.candidates_tried >= r.iterations);
    }

    #[test]
    fn netadapt_frontier_covers_every_accepted_iteration() {
        let m = Model::build(ModelKind::ResNet8Cifar, 0);
        let sim = Simulator::new(DeviceSpec::kryo385());
        let session = TuningSession::new(&sim, TuneOptions::quick(), 2);
        let mut oracle = ProxyOracle::new();
        let cfg = NetAdaptConfig {
            target_latency_ratio: 0.8,
            max_iterations: 6,
            ..Default::default()
        };
        let mut ctx = RunContext::standalone(&m, &session, &mut oracle);
        let po = netadapt_run(&mut ctx, &cfg);
        // frontier: baseline + accepted iterations + final, minus dominated
        assert!(!po.pareto.is_empty());
        assert!(po.pareto.len() <= po.iterations.len() + 2);
        for w in po.pareto.points().windows(2) {
            assert!(w[0].latency < w[1].latency);
            assert!(w[0].accuracy < w[1].accuracy);
        }
    }
}
