//! NetAdapt (Yang et al., ECCV 2018): platform-aware pruning by direct
//! per-layer measurement — the paper's strongest hardware-aware baseline
//! and the exhaustive-search reference of Fig. 11.
//!
//! Each iteration: for *every* prunable layer independently, find the
//! smallest filter count whose measured latency meets the iteration's
//! reduction budget; short-term fine-tune each candidate; keep the most
//! accurate one. This measures #layers candidates per iteration — the
//! cost CPrune's selective, impact-ordered search avoids (~90 % less,
//! Fig. 11).
//!
//! Faithful to the paper's Alg. with two environment substitutions: the
//! latency lookup is our device simulator via tuned compilation (NetAdapt
//! uses lookup tables of measured layer latencies), and short-term
//! accuracy is the shared oracle.

use super::Outcome;
use crate::accuracy::{AccuracyOracle, Criterion, TrainPhase};
use crate::compiler;
use crate::device::Simulator;
use crate::graph::model_zoo::Model;
use crate::graph::prune::{apply, PruneState};
use crate::graph::stats;
use crate::graph::weights::Weights;
use crate::tuner::TuningSession;
use std::collections::HashMap;
use std::time::Instant;

/// NetAdapt configuration.
#[derive(Clone, Debug)]
pub struct NetAdaptConfig {
    /// Fraction of current latency to remove per iteration (the paper's
    /// resource reduction schedule), e.g. 0.03.
    pub step_ratio: f64,
    /// Stop when latency ≤ this fraction of the original (budget).
    pub target_latency_ratio: f64,
    /// Accuracy floor for accepting a candidate (short-term).
    pub min_short_accuracy: f64,
    pub max_iterations: usize,
}

impl Default for NetAdaptConfig {
    fn default() -> Self {
        NetAdaptConfig {
            step_ratio: 0.04,
            target_latency_ratio: 0.6,
            min_short_accuracy: 0.0,
            max_iterations: 40,
        }
    }
}

/// Result, including the search-cost counters Fig. 11 plots.
#[derive(Clone, Debug)]
pub struct NetAdaptResult {
    pub outcome: Outcome,
    pub state: PruneState,
    pub iterations: usize,
    pub candidates_tried: usize,
}

pub fn netadapt(
    model: &Model,
    session: &TuningSession,
    sim: &Simulator,
    oracle: &mut dyn AccuracyOracle,
    cfg: &NetAdaptConfig,
) -> NetAdaptResult {
    let t0 = Instant::now();
    let base = compiler::compile_tuned(&model.graph, session, &HashMap::new());
    let base_latency = base.latency();
    let target = base_latency * cfg.target_latency_ratio;

    let mut state = PruneState::full(model);
    let mut weights = model.weights.clone();
    let mut cur_latency = base_latency;
    let mut candidates = 0usize;
    let mut iterations = 0usize;

    for _ in 0..cfg.max_iterations {
        if cur_latency <= target {
            break;
        }
        let budget = cur_latency * (1.0 - cfg.step_ratio);

        // Exhaustive per-layer candidate generation.
        let mut best: Option<(f64, PruneState, Weights, f64)> = None; // (acc, state, weights, lat)
        for &conv in &model.prunable {
            let remaining = state.remaining(conv);
            if remaining <= 2 {
                continue;
            }
            // Grow the pruned count until the measured latency meets the
            // budget (the paper walks its layer lookup table the same way).
            let mut k = (remaining / 8).max(1);
            let mut found: Option<(PruneState, Weights, f64)> = None;
            while k < remaining - 1 {
                let mut cand_state = state.clone();
                let mut cand_weights = weights.clone();
                let idx = Weights::lowest_k(&cand_weights.l1_norms(conv), k);
                cand_weights.remove_filters(conv, &idx);
                cand_state.shrink(conv, k);
                let Ok(g) = apply(&model.graph, &cand_state.cout) else { break };
                let lat = compiler::compile_tuned(&g, session, &HashMap::new()).latency();
                candidates += 1;
                if lat <= budget {
                    found = Some((cand_state, cand_weights, lat));
                    break;
                }
                k = (k * 2).min(remaining - 1);
                let _ = sim; // measurement goes through the tuned compile path
            }
            if let Some((cand_state, cand_weights, lat)) = found {
                let acc = oracle.top1(
                    &crate::pruner::summarize(model, &cand_state, Criterion::L1Norm),
                    TrainPhase::Short,
                );
                if acc >= cfg.min_short_accuracy
                    && best.as_ref().map(|(a, ..)| acc > *a).unwrap_or(true)
                {
                    best = Some((acc, cand_state, cand_weights, lat));
                }
            }
        }

        match best {
            Some((_, s, w, lat)) => {
                state = s;
                weights = w;
                cur_latency = lat;
                iterations += 1;
            }
            None => break, // no layer can meet the budget
        }
    }

    let graph = apply(&model.graph, &state.cout).expect("valid pruned graph");
    let compiled = compiler::compile_tuned(&graph, session, &HashMap::new());
    let (flops, params) = stats::flops_params(&graph);
    let summary = crate::pruner::summarize(model, &state, Criterion::L1Norm);
    let outcome = Outcome {
        method: "NetAdapt+TVM".into(),
        fps: compiled.fps(),
        fps_increase_rate: base_latency / compiled.latency(),
        macs: flops / 2,
        params,
        top1: oracle.top1(&summary, TrainPhase::Final),
        top5: oracle.top5(&summary, TrainPhase::Final),
        search_candidates: candidates,
        main_step_seconds: t0.elapsed().as_secs_f64(),
    };
    NetAdaptResult { outcome, state, iterations, candidates_tried: candidates }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accuracy::ProxyOracle;
    use crate::device::DeviceSpec;
    use crate::graph::model_zoo::ModelKind;
    use crate::tuner::TuneOptions;

    #[test]
    fn netadapt_reaches_latency_target_with_many_candidates() {
        let m = Model::build(ModelKind::ResNet8Cifar, 0);
        let sim = Simulator::new(DeviceSpec::kryo385());
        let session = TuningSession::new(&sim, TuneOptions::quick(), 2);
        let mut oracle = ProxyOracle::new();
        let cfg = NetAdaptConfig {
            target_latency_ratio: 0.8,
            max_iterations: 10,
            ..Default::default()
        };
        let r = netadapt(&m, &session, &sim, &mut oracle, &cfg);
        assert!(r.outcome.fps_increase_rate > 1.0);
        assert!(r.iterations >= 1);
        // exhaustive: candidates ≥ iterations (one per layer per iter at least)
        assert!(r.candidates_tried >= r.iterations);
    }
}
