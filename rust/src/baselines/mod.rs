//! Baseline pruning schemes compared against CPrune (Table 1/2, Figs. 1, 11).
//!
//! Every baseline is re-implemented on the same substrate (graph/relay/
//! tuner/device/accuracy) so the comparison isolates the *search policy*:
//!
//! * [`magnitude`] — uniform-ratio ℓ1 pruning (and random pruning for the
//!   Fig. 1 motivation experiment);
//! * [`fpgm`] — geometric-median filter pruning (He et al. 2019);
//! * [`amc`] — AutoML-for-model-compression, simplified to a greedy
//!   layer-wise sparsity policy with the same reward shape (acc·speed);
//! * [`netadapt`] — NetAdapt's per-layer empirical measurement loop
//!   (the exhaustive-search comparison of Fig. 11);
//! * [`pqf`] — permute-quantize-finetune, a non-structural comparator.
//!
//! Every baseline also runs behind the uniform [`crate::run::Pruner`]
//! trait (selected by name via [`crate::run::pruner_by_name`]); the free
//! functions in these modules are thin shims over those trait impls, so
//! both spellings produce byte-identical results for a fixed seed
//! (DESIGN.md §9). [`evaluate`] remains the legacy shared tail the run
//! layer's finalizer mirrors step for step.

pub mod amc;
pub mod fpgm;
pub mod magnitude;
pub mod netadapt;
pub mod pqf;

use crate::accuracy::{AccuracyOracle, Criterion, TrainPhase};
use crate::compiler;
use crate::device::Target;
use crate::graph::model_zoo::Model;
use crate::graph::prune::{apply, PruneState};
use crate::graph::stats;
use crate::graph::weights::Weights;
use crate::tuner::TuningSession;
use std::collections::HashMap;

/// A comparable outcome row (what Table 1/2 prints per method).
#[derive(Clone, Debug)]
pub struct Outcome {
    pub method: String,
    pub fps: f64,
    pub fps_increase_rate: f64,
    /// MACs of the final model (the tables' "FLOPS" column convention).
    pub macs: u64,
    pub params: u64,
    pub top1: f64,
    pub top5: f64,
    /// Candidate models evaluated during the search (0 = one-shot).
    pub search_candidates: usize,
    /// Wall-clock seconds of the search's main step.
    pub main_step_seconds: f64,
}

/// Uniformly prune `ratio` of every prunable conv's filters with the given
/// criterion. The base one-shot transform magnitude/FPGM/random build on.
pub fn uniform_prune(model: &Model, ratio: f64, criterion: Criterion, seed: u64) -> PruneState {
    let mut state = PruneState::full(model);
    let mut weights = model.weights.clone();
    let mut rng = crate::util::rng::Rng::new(seed);
    for &conv in &model.prunable {
        let total = state.remaining(conv);
        let k = ((total as f64 * ratio).round() as usize).min(total.saturating_sub(2));
        if k == 0 {
            continue;
        }
        let idx = match criterion {
            Criterion::L1Norm => Weights::lowest_k(&weights.l1_norms(conv), k),
            Criterion::GeomMedian => Weights::lowest_k(&weights.gm_distances(conv), k),
            Criterion::Random => {
                let mut all: Vec<usize> = (0..total).collect();
                rng.shuffle(&mut all);
                let mut sel = all[..k].to_vec();
                sel.sort_unstable();
                sel
            }
        };
        weights.remove_filters(conv, &idx);
        state.shrink(conv, k);
    }
    state
}

/// Per-layer (possibly non-uniform) pruning by explicit ratios.
pub fn per_layer_prune(
    model: &Model,
    ratios: &std::collections::BTreeMap<usize, f64>,
    criterion: Criterion,
) -> PruneState {
    let mut state = PruneState::full(model);
    let mut weights = model.weights.clone();
    for (&conv, &ratio) in ratios {
        if !state.cout.contains_key(&conv) {
            continue;
        }
        let total = state.remaining(conv);
        let k = ((total as f64 * ratio).round() as usize).min(total.saturating_sub(2));
        if k == 0 {
            continue;
        }
        let idx = match criterion {
            Criterion::GeomMedian => Weights::lowest_k(&weights.gm_distances(conv), k),
            _ => Weights::lowest_k(&weights.l1_norms(conv), k),
        };
        weights.remove_filters(conv, &idx);
        state.shrink(conv, k);
    }
    state
}

/// Compile a pruned state (tuned) and evaluate the Table-1 metrics.
pub fn evaluate(
    model: &Model,
    state: &PruneState,
    session: &TuningSession,
    oracle: &mut dyn AccuracyOracle,
    criterion: Criterion,
    method: &str,
    baseline_latency: f64,
) -> Outcome {
    let graph = apply(&model.graph, &state.cout).expect("valid pruned graph"); // cprune-lint: allow(CPL005, reason="pruners emit only valid states")
    let compiled = compiler::compile_tuned(&graph, session, &HashMap::new());
    let (flops, params) = stats::flops_params(&graph);
    let summary = crate::pruner::summarize(model, state, criterion);
    Outcome {
        method: method.to_string(),
        fps: compiled.fps(),
        fps_increase_rate: baseline_latency / compiled.latency(),
        macs: flops / 2,
        params,
        top1: oracle.top1(&summary, TrainPhase::Final),
        top5: oracle.top5(&summary, TrainPhase::Final),
        search_candidates: 0,
        main_step_seconds: 0.0,
    }
}

/// The unpruned, tuned reference row ("Original (TVM)").
pub fn original_row(model: &Model, session: &TuningSession) -> (Outcome, f64) {
    let compiled = compiler::compile_tuned(&model.graph, session, &HashMap::new());
    let (flops, params) = stats::flops_params(&model.graph);
    let (b1, b5) = model.kind.base_accuracy();
    let latency = compiled.latency();
    (
        Outcome {
            method: "Original (TVM)".into(),
            fps: compiled.fps(),
            fps_increase_rate: 1.0,
            macs: flops / 2,
            params,
            top1: b1,
            top5: b5,
            search_candidates: 0,
            main_step_seconds: 0.0,
        },
        latency,
    )
}

/// Convenience: fully evaluate a state on a fresh tuned compile — used by
/// benches that need FPS without the full Outcome.
pub fn fps_of_state(model: &Model, state: &PruneState, session: &TuningSession) -> f64 {
    let graph = apply(&model.graph, &state.cout).expect("valid pruned graph"); // cprune-lint: allow(CPL005, reason="pruners emit only valid states")
    compiler::compile_tuned(&graph, session, &HashMap::new()).fps()
}

/// FPS of a pruned state *without* compiler optimization (eager framework
/// execution: naive schedules + per-op dispatch) — the "before compiler
/// optimization" axis of Fig. 1.
pub fn fps_of_state_untuned(model: &Model, state: &PruneState, target: &dyn Target) -> f64 {
    let graph = apply(&model.graph, &state.cout).expect("valid pruned graph"); // cprune-lint: allow(CPL005, reason="pruners emit only valid states")
    compiler::compile_eager(&graph, target).fps()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::model_zoo::ModelKind;

    #[test]
    fn uniform_prune_ratio_respected() {
        let m = Model::build(ModelKind::Vgg16Cifar, 0);
        let st = uniform_prune(&m, 0.25, Criterion::L1Norm, 0);
        for &conv in &m.prunable {
            let full = PruneState::full(&m).remaining(conv);
            let now = st.remaining(conv);
            let frac = 1.0 - now as f64 / full as f64;
            assert!((frac - 0.25).abs() < 0.05, "conv {conv}: frac={frac}");
        }
    }

    #[test]
    fn random_prune_is_seeded() {
        // uniform_prune removes the same *count* per layer regardless of
        // seed (selection differs, counts do not) — determinism is what
        // matters for replay.
        let m = Model::build(ModelKind::Vgg16Cifar, 0);
        let a = uniform_prune(&m, 0.3, Criterion::Random, 5);
        let b = uniform_prune(&m, 0.3, Criterion::Random, 5);
        assert_eq!(a, b);
        let c = uniform_prune(&m, 0.3, Criterion::L1Norm, 5);
        assert_eq!(a.cout.len(), c.cout.len());
    }

    #[test]
    fn zero_ratio_is_identity() {
        let m = Model::build(ModelKind::ResNet8Cifar, 0);
        let st = uniform_prune(&m, 0.0, Criterion::L1Norm, 0);
        assert_eq!(st, PruneState::full(&m));
    }

    #[test]
    fn per_layer_prune_only_touches_requested() {
        let m = Model::build(ModelKind::Vgg16Cifar, 0);
        let mut ratios = std::collections::BTreeMap::new();
        ratios.insert(m.prunable[0], 0.5);
        let st = per_layer_prune(&m, &ratios, Criterion::L1Norm);
        let full = PruneState::full(&m);
        for &conv in &m.prunable[1..] {
            assert_eq!(st.remaining(conv), full.remaining(conv));
        }
        assert!(st.remaining(m.prunable[0]) < full.remaining(m.prunable[0]));
    }
}
