//! N:M block sparsity over the fan-in (DESIGN.md §16).
//!
//! Of every [`GROUP`] consecutive weights in a filter's flattened HWI
//! fan-in, the [`KEEP`] largest-magnitude survive (2:4 — the shape
//! structured-sparse hardware and libraries accelerate). The group
//! structure is metadata-light for the compiler: each group stores
//! which lanes survive, and the inner loop skips at fixed stride — no
//! filter reordering, unlike the pattern scheme
//! ([`crate::tir::sparse::SparseLowering::needs_reorder`]).

use crate::graph::ops::OpKind;

/// Survivors per group.
pub const KEEP: usize = 2;
/// Group size along the flattened fan-in.
pub const GROUP: usize = 4;
/// Weight density of a block-sparse layer.
pub const DENSITY: f64 = KEEP as f64 / GROUP as f64;

/// Whether the scheme can lower this operator: any non-grouped conv
/// whose fan-in holds at least one full group.
pub fn applicable(op: &OpKind) -> bool {
    match op {
        OpKind::Conv2d { kh, kw, cin, groups, .. } => {
            *groups == 1 && kh * kw * cin >= GROUP
        }
        _ => false,
    }
}

/// Keep-mask of one flattened filter: per group of [`GROUP`] consecutive
/// weights, the [`KEEP`] largest by |w| survive (ties keep the lower
/// index, for determinism). A trailing partial group stays dense — the
/// lowering falls back to the dense inner loop for the remainder, so
/// masking it would buy nothing.
pub fn keep_mask(filter: &[f32]) -> Vec<bool> {
    let mut mask = vec![true; filter.len()];
    let full_groups = filter.len() / GROUP;
    for g in 0..full_groups {
        let base = g * GROUP;
        let mut idx: [usize; GROUP] = [0; GROUP];
        for (k, slot) in idx.iter_mut().enumerate() {
            *slot = base + k;
        }
        idx.sort_by(|&a, &b| filter[b].abs().total_cmp(&filter[a].abs()).then(a.cmp(&b)));
        for &drop in &idx[KEEP..] {
            mask[drop] = false;
        }
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn density_is_two_of_four() {
        assert!((DENSITY - 0.5).abs() < 1e-12);
    }

    #[test]
    fn applicability_requires_full_group() {
        let ok = OpKind::Conv2d { kh: 1, kw: 1, cin: 16, cout: 8, stride: 1, padding: 0, groups: 1 };
        let tiny = OpKind::Conv2d { kh: 1, kw: 1, cin: 3, cout: 8, stride: 1, padding: 0, groups: 1 };
        let grouped = OpKind::Conv2d { kh: 3, kw: 3, cin: 16, cout: 16, stride: 1, padding: 1, groups: 16 };
        assert!(applicable(&ok));
        assert!(!applicable(&tiny));
        assert!(!applicable(&grouped));
        assert!(!applicable(&OpKind::Softmax));
    }

    #[test]
    fn keep_mask_keeps_two_largest_per_group() {
        let f = [0.1f32, 0.9, -0.8, 0.2, 0.5, 0.4, -0.3, 0.6];
        let m = keep_mask(&f);
        assert_eq!(m, vec![false, true, true, false, true, false, false, true]);
        assert_eq!(m.iter().filter(|&&b| b).count(), 4);
    }

    #[test]
    fn ties_keep_the_lower_index() {
        let f = [0.5f32, 0.5, 0.5, 0.5];
        assert_eq!(keep_mask(&f), vec![true, true, false, false]);
    }

    #[test]
    fn trailing_partial_group_stays_dense() {
        let f = [0.9f32, 0.1, 0.2, 0.8, 0.01, 0.02];
        let m = keep_mask(&f);
        assert_eq!(&m[..4], &[true, false, false, true]);
        assert_eq!(&m[4..], &[true, true], "partial tail group must stay dense");
    }
}
