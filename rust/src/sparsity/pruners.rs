//! Scheme-aware [`Pruner`] registry entries (DESIGN.md §16).
//!
//! * [`PatternPruner`] / [`BlockPruner`] — one-shot baselines that mask
//!   every applicable conv with the scheme's canonical choice and price
//!   the result through [`crate::sparsity::cost::masked_model_latency`]
//!   (the PatDNN / N:M "one scheme everywhere" reference points);
//! * [`SchemeSelect`] — the CPrune variant: the same subgraph-informed
//!   Algorithm-1 loop, but each selected task first tries *masking* its
//!   anchors with each allowed scheme (priced per device kind, no
//!   re-tune needed) before falling back to channel pruning. Whichever
//!   candidate passes the latency target and the accuracy gate is
//!   accepted, so the per-layer scheme assignment is decided by measured
//!   latency on the target device under the same α/β gates as channel
//!   moves — compiler-informed scheme selection.

use crate::accuracy::{Criterion, TrainPhase};
use crate::compiler;
use crate::graph::ops::NodeId;
use crate::graph::prune::{apply, PruneState};
use crate::graph::stats;
use crate::graph::weights::Weights;
use crate::pruner::{CPruneConfig, IterationLog};
use crate::relay::partition::partition;
use crate::run::{PruneOutcome, Pruner, RejectReason, RunContext, RunEvent};
use crate::serve::{Checkpoint, ParetoSet};
use crate::sparsity::{
    block, cost::masked_model_latency, masked_summary, pattern, Scheme, SchemeChoice, SchemeMap,
};
use crate::tir::{Program, Workload};
use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::time::Instant;

/// Per-conv weight densities of a scheme assignment — the shape
/// [`stats::effective_flops_params`] consumes.
fn densities(schemes: &SchemeMap) -> BTreeMap<NodeId, f64> {
    schemes.iter().map(|(&conv, choice)| (conv, choice.density)).collect()
}

/// Shared body of the one-shot scheme baselines: mask every applicable
/// conv, price the mask analytically over the tuned dense schedule, and
/// report the oracle's final accuracy of the masked summary.
fn one_shot_scheme(
    ctx: &mut RunContext,
    scheme: Scheme,
    name: &str,
    method: &str,
) -> PruneOutcome {
    let model = ctx.model;
    let session = ctx.session;
    let baseline_latency = ctx.baseline_latency();
    let compiled = compiler::compile_tuned(&model.graph, session, &HashMap::new());
    let part = partition(&model.graph);
    let kind = session.spec().kind;

    let mut schemes = SchemeMap::new();
    for &conv in &model.prunable {
        let op = &model.graph.node(conv).op;
        let ok = match scheme {
            Scheme::Pattern => pattern::applicable(op),
            Scheme::Block => block::applicable(op),
            Scheme::Channel => false,
        };
        if ok {
            schemes.insert(conv, SchemeChoice::for_scheme(scheme));
        }
    }

    let latency =
        masked_model_latency(&part, &compiled.table, compiled.overhead_latency, kind, &schemes);
    let state = PruneState::full(model);
    let summary = masked_summary(model, &state, &schemes, Criterion::L1Norm);
    let top1 = ctx.oracle.top1(&summary, TrainPhase::Final);
    let top5 = ctx.oracle.top5(&summary, TrainPhase::Final);
    let (flops, params) = stats::effective_flops_params(&model.graph, &densities(&schemes));
    let channels = state.cout;
    let checkpoint = Checkpoint {
        iteration: 1,
        latency,
        accuracy: top1,
        channels: channels.clone(),
        schemes: schemes.clone(),
    };
    ctx.emit(&RunEvent::CheckpointEmitted { checkpoint: checkpoint.clone() });
    let mut pareto = ParetoSet::new();
    pareto.insert(checkpoint);
    PruneOutcome {
        pruner: name.to_string(),
        method: method.to_string(),
        model: model.kind.name().to_string(),
        device: ctx.device().to_string(),
        baseline_latency,
        final_latency: latency,
        final_fps: 1.0 / latency,
        fps_increase_rate: baseline_latency / latency,
        macs: flops / 2,
        params,
        top1,
        top5,
        channels,
        pareto,
        iterations: Vec::new(),
        search_candidates: 0,
        main_step_seconds: 0.0,
        programs_measured: session.measured_count(),
    }
}

/// One-shot PatDNN-style pattern masking of every applicable 3×3 conv.
pub struct PatternPruner;

impl Pruner for PatternPruner {
    fn name(&self) -> &str {
        "pattern"
    }

    fn run(&self, ctx: &mut RunContext) -> PruneOutcome {
        one_shot_scheme(ctx, Scheme::Pattern, "pattern", "PatDNN(4-of-9)")
    }
}

/// One-shot 2:4 block masking of every applicable conv.
pub struct BlockPruner;

impl Pruner for BlockPruner {
    fn name(&self) -> &str {
        "block"
    }

    fn run(&self, ctx: &mut RunContext) -> PruneOutcome {
        one_shot_scheme(ctx, Scheme::Block, "block", "Block(2:4)")
    }
}

/// The CPrune scheme-selection variant: Algorithm 1's subgraph-informed
/// loop where every selected task offers its mask candidates *before*
/// its channel candidate, all judged by the same measured-latency target
/// `l_t = β·l_m` and short-accuracy gate `a_s ≥ α·a_p`.
pub struct SchemeSelect {
    pub cfg: CPruneConfig,
    /// Non-channel schemes the loop may assign. Channel pruning is
    /// always available (it is the fallback move, exactly CPrune).
    pub allowed: Vec<Scheme>,
    label: String,
}

impl Default for SchemeSelect {
    fn default() -> Self {
        SchemeSelect {
            cfg: CPruneConfig::default(),
            allowed: vec![Scheme::Pattern, Scheme::Block],
            label: "CPrune+SchemeSelect".to_string(),
        }
    }
}

impl SchemeSelect {
    /// Auto scheme search under an explicit CPrune configuration
    /// (mirrors [`crate::run::CPrune::with_cfg`] for equal-budget
    /// comparisons).
    pub fn with_cfg(cfg: CPruneConfig) -> SchemeSelect {
        SchemeSelect {
            cfg,
            ..SchemeSelect::default()
        }
    }

    /// Build from the CLI's `--scheme` flag: `auto` considers every
    /// non-channel scheme, a scheme name restricts the search to it, and
    /// `channel` disables masking (plain CPrune moves under this
    /// pruner's accounting).
    pub fn from_scheme_flag(flag: &str) -> Result<SchemeSelect, String> {
        let mut sel = SchemeSelect::default();
        match flag {
            "auto" => {}
            "channel" => sel.allowed = Vec::new(),
            other => match Scheme::from_name(other) {
                Some(Scheme::Channel) | None => {
                    return Err(format!(
                        "unknown --scheme '{flag}' (expected auto, channel, pattern or block)"
                    ));
                }
                Some(s) => sel.allowed = vec![s],
            },
        }
        Ok(sel)
    }

    fn effective_cfg(&self, ctx: &RunContext) -> CPruneConfig {
        let mut cfg = self.cfg.clone();
        if let Some(a) = ctx.accuracy_budget {
            cfg.target_accuracy = a;
        }
        if let Some(n) = ctx.max_iterations {
            cfg.max_iterations = n;
        }
        cfg
    }
}

impl Pruner for SchemeSelect {
    fn name(&self) -> &str {
        "scheme-select"
    }

    fn run(&self, ctx: &mut RunContext) -> PruneOutcome {
        let cfg = self.effective_cfg(ctx);
        let t0 = Instant::now();
        let model = ctx.model;
        let session = ctx.session;
        let kind = session.spec().kind;

        // Line 1: initial tune of M.
        let baseline = compiler::compile_tuned(&model.graph, session, &HashMap::new());
        let base_latency = baseline.latency();
        ctx.set_baseline(base_latency, baseline.fps());

        let mut state = PruneState::full(model);
        let mut weights = model.weights.clone();
        let mut graph = model.graph.clone();
        let mut table = baseline.table.clone();
        let mut overhead = baseline.overhead_latency;
        let mut schemes = SchemeMap::new();
        let mut l_t = cfg.beta * base_latency;
        let mut a_p = ctx
            .oracle
            .top1(&masked_summary(model, &state, &schemes, cfg.criterion), TrainPhase::Short);
        let mut banned: BTreeSet<NodeId> = BTreeSet::new();
        let mut mask_rejected: BTreeSet<(NodeId, Scheme)> = BTreeSet::new();
        let mut iterations: Vec<IterationLog> = Vec::new();
        let mut candidates_tried = 0usize;

        let mut pareto = ParetoSet::new();
        let baseline_checkpoint = Checkpoint {
            iteration: 0,
            latency: base_latency,
            accuracy: a_p,
            channels: state.cout.clone(),
            schemes: SchemeMap::new(),
        };
        ctx.emit(&RunEvent::CheckpointEmitted { checkpoint: baseline_checkpoint.clone() });
        pareto.insert(baseline_checkpoint);

        'outer: for iter_no in 0..cfg.max_iterations {
            if a_p <= cfg.target_accuracy || candidates_tried >= cfg.max_candidates {
                break;
            }
            let part = partition(&graph);
            let ordered = table.by_pruning_impact();

            let mut accepted = false;
            for tid in ordered {
                let tinfo = table.get(tid).clone();
                let anchors: Vec<NodeId> = tinfo
                    .subgraphs
                    .iter()
                    .filter_map(|&sgid| part.subgraphs.get(sgid).map(|s| s.anchor))
                    .collect();
                if anchors.is_empty()
                    || anchors.iter().any(|a| banned.contains(a))
                    || !anchors.iter().all(|a| state.cout.contains_key(a))
                {
                    continue;
                }

                // -- Mask candidates first: price each allowed scheme over
                // the *current* tuned table (no re-tune) and keep the ones
                // passing the latency target, cheapest first.
                let mut mask_cands: Vec<(Scheme, f64)> = Vec::new();
                for &scheme in &self.allowed {
                    if anchors.iter().any(|a| schemes.contains_key(a))
                        || mask_rejected.contains(&(anchors[0], scheme))
                    {
                        continue;
                    }
                    let applicable = anchors.iter().all(|&a| {
                        let op = &graph.node(a).op;
                        match scheme {
                            Scheme::Pattern => pattern::applicable(op),
                            Scheme::Block => block::applicable(op),
                            Scheme::Channel => false,
                        }
                    });
                    if !applicable {
                        continue;
                    }
                    let mut cand_schemes = schemes.clone();
                    for &a in &anchors {
                        cand_schemes.insert(a, SchemeChoice::for_scheme(scheme));
                    }
                    let l_m = masked_model_latency(&part, &table, overhead, kind, &cand_schemes);
                    candidates_tried += 1;
                    ctx.emit(&RunEvent::CandidateMeasured {
                        iteration: iter_no + 1,
                        latency: l_m,
                        latency_target: l_t,
                        candidates_tried,
                        scheme: Some(scheme),
                    });
                    if candidates_tried > cfg.max_candidates {
                        break 'outer;
                    }
                    if l_m >= l_t {
                        ctx.emit(&RunEvent::IterationRejected {
                            iteration: iter_no + 1,
                            latency: l_m,
                            latency_target: l_t,
                            short_accuracy: None,
                            accuracy_gate: None,
                            reason: RejectReason::LatencyGate,
                        });
                        continue;
                    }
                    mask_cands.push((scheme, l_m));
                }
                mask_cands.sort_by(|a, b| a.1.total_cmp(&b.1));

                // Accuracy-gate the surviving masks, fastest first.
                for (scheme, l_m) in mask_cands {
                    let mut cand_schemes = schemes.clone();
                    for &a in &anchors {
                        cand_schemes.insert(a, SchemeChoice::for_scheme(scheme));
                    }
                    let a_s = ctx.oracle.top1(
                        &masked_summary(model, &state, &cand_schemes, cfg.criterion),
                        TrainPhase::Short,
                    );
                    if a_s < cfg.alpha * a_p {
                        // Remember the refusal per (task, scheme) — the
                        // task itself stays live for channel pruning.
                        mask_rejected.insert((anchors[0], scheme));
                        ctx.emit(&RunEvent::IterationRejected {
                            iteration: iter_no + 1,
                            latency: l_m,
                            latency_target: l_t,
                            short_accuracy: Some(a_s),
                            accuracy_gate: Some(cfg.alpha * a_p),
                            reason: RejectReason::AccuracyGate,
                        });
                        continue;
                    }
                    if a_s <= cfg.target_accuracy {
                        ctx.emit(&RunEvent::IterationRejected {
                            iteration: iter_no + 1,
                            latency: l_m,
                            latency_target: l_t,
                            short_accuracy: Some(a_s),
                            accuracy_gate: Some(cfg.target_accuracy),
                            reason: RejectReason::AccuracyBudget,
                        });
                        break 'outer;
                    }
                    // Accept the mask move.
                    schemes = cand_schemes;
                    ctx.emit(&RunEvent::IterationAccepted {
                        iteration: iter_no + 1,
                        latency: l_m,
                        latency_target: l_t,
                        short_accuracy: a_s,
                        accuracy_gate: cfg.alpha * a_p,
                        filters_removed: 0,
                        scheme: Some(scheme),
                    });
                    let accepted_target = l_t;
                    let accepted_gate = cfg.alpha * a_p;
                    l_t = cfg.beta * l_m;
                    a_p = a_s;
                    let checkpoint = Checkpoint {
                        iteration: iter_no + 1,
                        latency: l_m,
                        accuracy: a_s,
                        channels: state.cout.clone(),
                        schemes: schemes.clone(),
                    };
                    ctx.emit(&RunEvent::CheckpointEmitted { checkpoint: checkpoint.clone() });
                    ctx.journal_accept(crate::run::journal::IterationRecord {
                        iteration: iter_no + 1,
                        latency: l_m,
                        latency_target: accepted_target,
                        short_accuracy: a_s,
                        accuracy_gate: accepted_gate,
                        filters_removed: 0,
                        candidates_tried,
                        checkpoint: checkpoint.clone(),
                    });
                    pareto.insert(checkpoint);
                    iterations.push(IterationLog {
                        iteration: iter_no + 1,
                        pruned_convs: anchors.clone(),
                        filters_removed: 0,
                        latency: l_m,
                        fps_rate: base_latency / l_m,
                        short_accuracy: a_s,
                        candidates_tried,
                    });
                    accepted = true;
                    break;
                }
                if accepted {
                    break;
                }

                // -- Channel fallback: exactly the CPrune move, priced and
                // accuracy-gated under the current scheme assignment.
                let Some(prog) = tinfo.best_program.clone() else { continue };
                let step = prog.min_filter_prune_step().max(1);
                let remaining = state.remaining(anchors[0]);
                if remaining <= 2 || remaining.saturating_sub(step) < 2 {
                    banned.insert(anchors[0]);
                    ctx.emit(&RunEvent::TaskBanned {
                        conv: anchors[0],
                        reason: "channel_floor".to_string(),
                    });
                    continue;
                }
                let targets: Vec<NodeId> = if cfg.associated_subgraphs {
                    anchors.clone()
                } else {
                    vec![anchors[0]]
                };

                for mult in [1usize, 2, 4, 8] {
                    let k_want = step * mult;
                    if k_want >= remaining.saturating_sub(2) && mult > 1 {
                        break;
                    }
                    let mut cand_state = state.clone();
                    let mut cand_weights = weights.clone();
                    let mut removed_total = 0usize;
                    for &conv in &targets {
                        let scores = match cfg.criterion {
                            Criterion::GeomMedian => cand_weights.gm_distances(conv),
                            _ => cand_weights.l1_norms(conv),
                        };
                        let k = k_want.min(cand_state.remaining(conv).saturating_sub(2));
                        if k == 0 {
                            continue;
                        }
                        let idx = Weights::lowest_k(&scores, k);
                        cand_weights.remove_filters(conv, &idx);
                        removed_total += cand_state.shrink(conv, k);
                    }
                    if removed_total == 0 {
                        banned.insert(anchors[0]);
                        ctx.emit(&RunEvent::TaskBanned {
                            conv: anchors[0],
                            reason: "no_channels_removed".to_string(),
                        });
                        break;
                    }
                    let cand_graph = match apply(&model.graph, &cand_state.cout) {
                        Ok(g) => g,
                        Err(_) => {
                            banned.insert(anchors[0]);
                            ctx.emit(&RunEvent::TaskBanned {
                                conv: anchors[0],
                                reason: "invalid_graph".to_string(),
                            });
                            break;
                        }
                    };

                    let mut seeds: HashMap<Workload, Program> = HashMap::new();
                    let new_ff = cand_state.remaining(targets[0]);
                    if let Some(adj) = prog.with_pruned_filters(new_ff) {
                        let mut w2 = tinfo.workload.clone();
                        w2.ff = new_ff;
                        seeds.insert(w2, adj);
                    }
                    let cand = compiler::compile_tuned(&cand_graph, session, &seeds);
                    let cand_part = partition(&cand_graph);
                    let l_m = masked_model_latency(
                        &cand_part,
                        &cand.table,
                        cand.overhead_latency,
                        kind,
                        &schemes,
                    );
                    candidates_tried += 1;
                    ctx.emit(&RunEvent::CandidateMeasured {
                        iteration: iter_no + 1,
                        latency: l_m,
                        latency_target: l_t,
                        candidates_tried,
                        scheme: Some(Scheme::Channel),
                    });
                    if candidates_tried > cfg.max_candidates {
                        break 'outer;
                    }
                    if l_m >= l_t {
                        ctx.emit(&RunEvent::IterationRejected {
                            iteration: iter_no + 1,
                            latency: l_m,
                            latency_target: l_t,
                            short_accuracy: None,
                            accuracy_gate: None,
                            reason: RejectReason::LatencyGate,
                        });
                        continue;
                    }
                    let a_s = ctx.oracle.top1(
                        &masked_summary(model, &cand_state, &schemes, cfg.criterion),
                        TrainPhase::Short,
                    );
                    if a_s < cfg.alpha * a_p {
                        banned.insert(anchors[0]);
                        ctx.emit(&RunEvent::IterationRejected {
                            iteration: iter_no + 1,
                            latency: l_m,
                            latency_target: l_t,
                            short_accuracy: Some(a_s),
                            accuracy_gate: Some(cfg.alpha * a_p),
                            reason: RejectReason::AccuracyGate,
                        });
                        ctx.emit(&RunEvent::TaskBanned {
                            conv: anchors[0],
                            reason: "accuracy_gate".to_string(),
                        });
                        break;
                    }
                    if a_s <= cfg.target_accuracy {
                        ctx.emit(&RunEvent::IterationRejected {
                            iteration: iter_no + 1,
                            latency: l_m,
                            latency_target: l_t,
                            short_accuracy: Some(a_s),
                            accuracy_gate: Some(cfg.target_accuracy),
                            reason: RejectReason::AccuracyBudget,
                        });
                        break 'outer;
                    }
                    state = cand_state;
                    weights = cand_weights;
                    graph = cand_graph;
                    table = cand.table;
                    overhead = cand.overhead_latency;
                    ctx.emit(&RunEvent::IterationAccepted {
                        iteration: iter_no + 1,
                        latency: l_m,
                        latency_target: l_t,
                        short_accuracy: a_s,
                        accuracy_gate: cfg.alpha * a_p,
                        filters_removed: removed_total,
                        scheme: Some(Scheme::Channel),
                    });
                    let accepted_target = l_t;
                    let accepted_gate = cfg.alpha * a_p;
                    l_t = cfg.beta * l_m;
                    a_p = a_s;
                    let checkpoint = Checkpoint {
                        iteration: iter_no + 1,
                        latency: l_m,
                        accuracy: a_s,
                        channels: state.cout.clone(),
                        schemes: schemes.clone(),
                    };
                    ctx.emit(&RunEvent::CheckpointEmitted { checkpoint: checkpoint.clone() });
                    ctx.journal_accept(crate::run::journal::IterationRecord {
                        iteration: iter_no + 1,
                        latency: l_m,
                        latency_target: accepted_target,
                        short_accuracy: a_s,
                        accuracy_gate: accepted_gate,
                        filters_removed: removed_total,
                        candidates_tried,
                        checkpoint: checkpoint.clone(),
                    });
                    pareto.insert(checkpoint);
                    iterations.push(IterationLog {
                        iteration: iter_no + 1,
                        pruned_convs: targets.clone(),
                        filters_removed: removed_total,
                        latency: l_m,
                        fps_rate: base_latency / l_m,
                        short_accuracy: a_s,
                        candidates_tried,
                    });
                    accepted = true;
                    break;
                }
                if accepted {
                    break;
                }
            }
            if !accepted {
                break;
            }
        }
        let main_step_seconds = t0.elapsed().as_secs_f64();

        // Final tune + masked evaluation of the end state.
        let final_compiled = compiler::compile_tuned(&graph, session, &HashMap::new());
        let final_latency = masked_model_latency(
            &partition(&graph),
            &final_compiled.table,
            final_compiled.overhead_latency,
            kind,
            &schemes,
        );
        let summary = masked_summary(model, &state, &schemes, cfg.criterion);
        let top1 = ctx.oracle.top1(&summary, TrainPhase::Final);
        let top5 = ctx.oracle.top5(&summary, TrainPhase::Final);
        let (flops, params) = stats::effective_flops_params(&graph, &densities(&schemes));

        PruneOutcome {
            pruner: self.name().to_string(),
            method: self.label.clone(),
            model: model.kind.name().to_string(),
            device: ctx.device().to_string(),
            baseline_latency: base_latency,
            final_latency,
            final_fps: 1.0 / final_latency,
            fps_increase_rate: base_latency / final_latency,
            macs: flops / 2,
            params,
            top1,
            top5,
            channels: state.cout,
            pareto,
            iterations,
            search_candidates: candidates_tried,
            main_step_seconds,
            programs_measured: session.measured_count(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheme_flag_parses_every_documented_value() {
        assert_eq!(
            SchemeSelect::from_scheme_flag("auto").unwrap().allowed,
            vec![Scheme::Pattern, Scheme::Block]
        );
        assert!(SchemeSelect::from_scheme_flag("channel").unwrap().allowed.is_empty());
        assert_eq!(
            SchemeSelect::from_scheme_flag("pattern").unwrap().allowed,
            vec![Scheme::Pattern]
        );
        assert_eq!(SchemeSelect::from_scheme_flag("block").unwrap().allowed, vec![Scheme::Block]);
        assert!(SchemeSelect::from_scheme_flag("vibes").is_err());
    }

    #[test]
    fn registry_names_are_stable() {
        assert_eq!(PatternPruner.name(), "pattern");
        assert_eq!(BlockPruner.name(), "block");
        assert_eq!(SchemeSelect::default().name(), "scheme-select");
    }
}
