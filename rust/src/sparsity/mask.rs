//! The versioned sparse-mask artifact (DESIGN.md §16).
//!
//! `cprune-sparsity-masks` v1 records, per masked conv, the scheme, its
//! weight density, and the scheme's parameters: the sorted library
//! indices a pattern assignment uses ([`crate::sparsity::pattern`]), or
//! `[keep, group]` for block sparsity. Layered onto
//! [`crate::graph::weights::Weights`] (which taps survive) and
//! [`crate::graph::prune::PruneState`] (which channels survive) this is
//! a complete description of a sparse deployable. Verified under the
//! CPV17x codes ([`crate::verify::artifact`]); written only through
//! [`crate::util::io::atomic_write`] (DESIGN.md §15).

use crate::graph::ops::{Graph, NodeId, OpKind};
use crate::graph::weights::Weights;
use crate::sparsity::{block, pattern, Scheme, SchemeChoice, SchemeMap};
use crate::util::json::Json;
use std::path::Path;

/// Artifact format tag.
pub const MASKS_FORMAT: &str = "cprune-sparsity-masks";
/// Current artifact version.
pub const MASKS_VERSION: u64 = 1;

/// One conv's mask record.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerMask {
    /// Conv node id in the original graph.
    pub conv: NodeId,
    /// Scheme + density of the layer.
    pub choice: SchemeChoice,
    /// Scheme parameters: pattern → sorted distinct library indices in
    /// use; block → `[keep, group]`; channel → empty.
    pub params: Vec<usize>,
}

impl LayerMask {
    /// Canonical JSON object.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("conv", Json::Num(self.conv as f64)),
            ("density", Json::Num(self.choice.density)),
            (
                "params",
                Json::Arr(self.params.iter().map(|&p| Json::Num(p as f64)).collect()),
            ),
            ("scheme", Json::Str(self.choice.scheme.name().to_string())),
        ])
    }

    /// Parse a record previously written by [`LayerMask::to_json`].
    pub fn from_json(j: &Json) -> Result<LayerMask, String> {
        let conv = j
            .get("conv")
            .and_then(Json::as_usize)
            .ok_or_else(|| "mask entry missing conv".to_string())?;
        let choice = SchemeChoice::from_json(j)?;
        let params = match j.get("params") {
            Some(Json::Arr(items)) => items
                .iter()
                .map(|p| p.as_usize().ok_or_else(|| "non-integer mask param".to_string()))
                .collect::<Result<Vec<usize>, String>>()?,
            Some(_) => return Err("mask params must be an array".to_string()),
            None => return Err("mask entry missing params".to_string()),
        };
        Ok(LayerMask { conv, choice, params })
    }
}

/// A model's mask records, sorted by conv id.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MaskSet {
    pub masks: Vec<LayerMask>,
}

impl MaskSet {
    pub fn new() -> MaskSet {
        MaskSet::default()
    }

    /// Insert (or replace) a conv's record, keeping the set sorted.
    pub fn insert(&mut self, mask: LayerMask) {
        match self.masks.binary_search_by_key(&mask.conv, |m| m.conv) {
            Ok(i) => self.masks[i] = mask,
            Err(i) => self.masks.insert(i, mask),
        }
    }

    /// Record of one conv, if masked.
    pub fn get(&self, conv: NodeId) -> Option<&LayerMask> {
        self.masks
            .binary_search_by_key(&conv, |m| m.conv)
            .ok()
            .map(|i| &self.masks[i])
    }

    /// Materialize a scheme assignment into records, deriving each
    /// scheme's parameters from the current weight bank: the pattern
    /// indices each filter selects by retained ℓ1 mass, or the block
    /// shape. Channel entries record no parameters.
    pub fn from_schemes(schemes: &SchemeMap, graph: &Graph, weights: &Weights) -> MaskSet {
        let mut set = MaskSet::new();
        for (&conv, choice) in schemes {
            let params = match choice.scheme {
                Scheme::Channel => Vec::new(),
                Scheme::Pattern => {
                    let cin_g = match graph.node(conv).op {
                        OpKind::Conv2d { cin, groups, .. } => cin / groups.max(1),
                        _ => 1,
                    };
                    pattern::used_patterns(&pattern::assignment(weights, conv, cin_g))
                }
                Scheme::Block => vec![block::KEEP, block::GROUP],
            };
            set.insert(LayerMask { conv, choice: *choice, params });
        }
        set
    }

    /// The scheme assignment these records describe.
    pub fn to_schemes(&self) -> SchemeMap {
        self.masks.iter().map(|m| (m.conv, m.choice)).collect()
    }

    /// Canonical JSON document.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("format", Json::Str(MASKS_FORMAT.to_string())),
            ("masks", Json::Arr(self.masks.iter().map(LayerMask::to_json).collect())),
            ("version", Json::Num(MASKS_VERSION as f64)),
        ])
    }

    /// Parse a document previously written by [`MaskSet::save`].
    pub fn parse(text: &str) -> Result<MaskSet, String> {
        let j = crate::util::json::parse(text)?;
        let format = j.get("format").and_then(Json::as_str);
        if format != Some(MASKS_FORMAT) {
            return Err(format!("not a {MASKS_FORMAT} document"));
        }
        let version = j.get("version").and_then(Json::as_f64);
        if version != Some(MASKS_VERSION as f64) {
            return Err(format!("unsupported {MASKS_FORMAT} version"));
        }
        let masks = match j.get("masks") {
            Some(Json::Arr(items)) => items
                .iter()
                .map(LayerMask::from_json)
                .collect::<Result<Vec<LayerMask>, String>>()?,
            _ => return Err("mask document missing masks array".to_string()),
        };
        for w in masks.windows(2) {
            if w[0].conv >= w[1].conv {
                return Err(format!(
                    "mask entries out of order: conv {} before conv {}",
                    w[0].conv, w[1].conv
                ));
            }
        }
        Ok(MaskSet { masks })
    }

    /// Write the mask set atomically ([`crate::util::io::atomic_write`],
    /// DESIGN.md §15).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<(), String> {
        let path = path.as_ref();
        let text = self.to_json().to_string();
        #[cfg(debug_assertions)]
        if let Some(d) =
            crate::verify::artifact::check_text(&text).and_then(|ds| ds.into_iter().next())
        {
            panic!("MaskSet::save produced a non-canonical document: {d}");
        }
        crate::util::io::atomic_write(path, &text, "sparsity masks")
    }

    /// Load a mask set previously written by [`MaskSet::save`].
    pub fn load(path: impl AsRef<Path>) -> Result<MaskSet, String> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        Self::parse(&text).map_err(|e| format!("{}: {e}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::model_zoo::{Model, ModelKind};

    fn sample() -> MaskSet {
        let mut set = MaskSet::new();
        set.insert(LayerMask { conv: 7, choice: SchemeChoice::block(), params: vec![2, 4] });
        set.insert(LayerMask {
            conv: 3,
            choice: SchemeChoice::pattern(),
            params: vec![0, 2],
        });
        set
    }

    #[test]
    fn insert_keeps_records_sorted_and_replaces() {
        let mut set = sample();
        assert_eq!(set.masks[0].conv, 3);
        assert_eq!(set.masks[1].conv, 7);
        set.insert(LayerMask { conv: 3, choice: SchemeChoice::block(), params: vec![2, 4] });
        assert_eq!(set.masks.len(), 2);
        assert_eq!(set.get(3).unwrap().choice.scheme, Scheme::Block);
        assert!(set.get(5).is_none());
    }

    #[test]
    fn document_round_trips_canonically() {
        let set = sample();
        let text = set.to_json().to_string();
        let back = MaskSet::parse(&text).unwrap();
        assert_eq!(back, set);
        assert_eq!(back.to_json().to_string(), text);
        assert_eq!(back.to_schemes().len(), 2);
    }

    #[test]
    fn parse_rejects_malformed_documents() {
        assert!(MaskSet::parse("{}").is_err());
        let wrong_version = r#"{"format":"cprune-sparsity-masks","masks":[],"version":9}"#;
        assert!(MaskSet::parse(wrong_version).is_err());
        let unsorted = r#"{"format":"cprune-sparsity-masks","masks":[
            {"conv":7,"density":0.5,"params":[2,4],"scheme":"block"},
            {"conv":3,"density":0.5,"params":[2,4],"scheme":"block"}],"version":1}"#;
        assert!(MaskSet::parse(unsorted).is_err());
        let bad_scheme = r#"{"format":"cprune-sparsity-masks","masks":[
            {"conv":3,"density":0.5,"params":[],"scheme":"vibes"}],"version":1}"#;
        assert!(MaskSet::parse(bad_scheme).is_err());
    }

    #[test]
    fn from_schemes_derives_parameters_from_weights() {
        let m = Model::build(ModelKind::ResNet8Cifar, 0);
        let conv = m.prunable[0];
        let mut schemes = SchemeMap::new();
        schemes.insert(conv, SchemeChoice::pattern());
        let set = MaskSet::from_schemes(&schemes, &m.graph, &m.weights);
        let rec = set.get(conv).unwrap();
        assert!(!rec.params.is_empty(), "pattern mask must record its library indices");
        assert!(rec.params.windows(2).all(|w| w[0] < w[1]));
        assert!(rec.params.iter().all(|&p| p < pattern::PATTERNS.len()));

        let mut blocks = SchemeMap::new();
        blocks.insert(conv, SchemeChoice::block());
        let bset = MaskSet::from_schemes(&blocks, &m.graph, &m.weights);
        assert_eq!(bset.get(conv).unwrap().params, vec![block::KEEP, block::GROUP]);
    }

    #[test]
    fn save_and_load_round_trip() {
        let path = std::env::temp_dir().join("cprune_sparsity_mask_unit_test.json");
        let set = sample();
        set.save(&path).unwrap();
        let back = MaskSet::load(&path).unwrap();
        assert_eq!(back, set);
        std::fs::remove_file(&path).ok();
    }
}
