//! Sparsity schemes beyond whole-channel pruning (DESIGN.md §16).
//!
//! CPrune's loop prunes channels because that is the structure its
//! compiler can shrink densely. PatDNN (arXiv 2001.00138) showed that
//! *pattern-based* intra-kernel sparsity plus connectivity pruning is
//! also compiler-exploitable on mobile targets, and the "Automatic
//! Mapping" line of work (arXiv 2111.11581) showed that selecting the
//! best scheme *per layer* beats any single scheme everywhere. This
//! module is the vocabulary that makes those schemes first-class:
//!
//! * [`Scheme`] / [`SchemeChoice`] — which sparsity class a layer uses
//!   and at what weight density;
//! * [`pattern`] — the PatDNN-style 3×3 kernel-pattern library;
//! * [`block`] — N:M (2:4) block sparsity over the fan-in;
//! * [`mask`] — the versioned `cprune-sparsity-masks` artifact layered
//!   onto [`crate::graph::weights::Weights`] +
//!   [`crate::graph::prune::PruneState`];
//! * [`cost`] — mask-aware analytic latency over a compiled
//!   [`crate::relay::TaskTable`], priced per device kind through
//!   [`crate::device::sparse::scheme_factor`] and the lowering classes
//!   in [`crate::tir::sparse`];
//! * [`pruners`] — the `pattern` / `block` one-shot pruners and the
//!   `scheme-select` CPrune variant that picks the scheme per task by
//!   measured latency under the accuracy gate.

pub mod block;
pub mod cost;
pub mod mask;
pub mod pattern;
pub mod pruners;

pub use cost::masked_model_latency;
pub use mask::{LayerMask, MaskSet, MASKS_FORMAT, MASKS_VERSION};
pub use pruners::{BlockPruner, PatternPruner, SchemeSelect};

use crate::accuracy::{Criterion, LayerPrune, PruneSummary};
use crate::graph::model_zoo::Model;
use crate::graph::ops::{NodeId, OpKind};
use crate::graph::prune::PruneState;
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Sparsity class of one conv layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Scheme {
    /// Dense channel shrink — the classic CPrune structure. Density 1.0
    /// within the remaining channels.
    Channel,
    /// PatDNN-style kernel patterns: every 3×3 kernel keeps the same
    /// number of taps, drawn from a small library
    /// ([`pattern::PATTERNS`]), so the compiler can compact and reorder.
    Pattern,
    /// N:M block sparsity ([`block::KEEP`] of every [`block::GROUP`]
    /// consecutive fan-in weights survive).
    Block,
}

impl Scheme {
    /// Every scheme, in registry/display order.
    pub const ALL: [Scheme; 3] = [Scheme::Channel, Scheme::Pattern, Scheme::Block];

    /// Stable registry/artifact name.
    pub fn name(self) -> &'static str {
        match self {
            Scheme::Channel => "channel",
            Scheme::Pattern => "pattern",
            Scheme::Block => "block",
        }
    }

    /// Inverse of [`Scheme::name`]. `None` for unknown names.
    pub fn from_name(name: &str) -> Option<Scheme> {
        match name {
            "channel" => Some(Scheme::Channel),
            "pattern" => Some(Scheme::Pattern),
            "block" => Some(Scheme::Block),
            _ => None,
        }
    }
}

/// A layer's selected scheme plus its weight density (kept fraction of
/// the remaining channels' weights; 1.0 for [`Scheme::Channel`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SchemeChoice {
    pub scheme: Scheme,
    pub density: f64,
}

impl SchemeChoice {
    /// Dense channel shrink (the implicit default everywhere a layer has
    /// no recorded choice).
    pub fn channel() -> SchemeChoice {
        SchemeChoice { scheme: Scheme::Channel, density: 1.0 }
    }

    /// The library's 4-of-9 kernel patterns.
    pub fn pattern() -> SchemeChoice {
        SchemeChoice { scheme: Scheme::Pattern, density: pattern::DENSITY }
    }

    /// 2:4 block sparsity.
    pub fn block() -> SchemeChoice {
        SchemeChoice { scheme: Scheme::Block, density: block::DENSITY }
    }

    /// Canonical default choice for a scheme.
    pub fn for_scheme(scheme: Scheme) -> SchemeChoice {
        match scheme {
            Scheme::Channel => SchemeChoice::channel(),
            Scheme::Pattern => SchemeChoice::pattern(),
            Scheme::Block => SchemeChoice::block(),
        }
    }

    /// Canonical JSON object (keys sorted by [`Json::obj`]).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("density", Json::Num(self.density)),
            ("scheme", Json::Str(self.scheme.name().to_string())),
        ])
    }

    /// Parse a choice previously written by [`SchemeChoice::to_json`].
    pub fn from_json(j: &Json) -> Result<SchemeChoice, String> {
        let name = j
            .get("scheme")
            .and_then(Json::as_str)
            .ok_or_else(|| "scheme choice missing scheme".to_string())?;
        let scheme =
            Scheme::from_name(name).ok_or_else(|| format!("unknown scheme '{name}'"))?;
        let density = j
            .get("density")
            .and_then(Json::as_f64)
            .ok_or_else(|| "scheme choice missing density".to_string())?;
        if !density.is_finite() || density <= 0.0 || density > 1.0 {
            return Err(format!("scheme density {density} outside (0, 1]"));
        }
        Ok(SchemeChoice { scheme, density })
    }
}

/// Per-conv scheme assignment. Layers absent from the map are dense
/// channel layers — the representation every pre-sparsity artifact
/// implicitly used, which keeps v1 registries loadable unchanged.
pub type SchemeMap = BTreeMap<NodeId, SchemeChoice>;

/// Accuracy-retention exponent of a scheme: masking a layer to weight
/// density `d` costs accuracy like shrinking its channels to
/// `d^exp` of the remaining count. Patterns retain more than blocks at
/// equal density (the kept taps are chosen per kernel by magnitude and
/// every pattern keeps the center tap; 2:4 has no such freedom across
/// groups) — the calibration PatDNN/N:M fine-tuning results point at.
fn retention_exponent(scheme: Scheme) -> f64 {
    match scheme {
        Scheme::Channel => 1.0,
        Scheme::Pattern => 0.55,
        Scheme::Block => 0.7,
    }
}

/// Oracle-facing channel count of a masked layer: the density raised to
/// the scheme's retention exponent, applied to the remaining channels
/// (floor 2, never above the dense count).
pub fn effective_channels(remaining: usize, choice: &SchemeChoice) -> usize {
    let eff = (remaining as f64 * choice.density.powf(retention_exponent(choice.scheme))).round();
    let eff = eff as usize;
    eff.max(2).min(remaining)
}

/// Build the oracle-facing summary of a pruning state *plus* a scheme
/// assignment — the sparsity-aware sibling of
/// [`crate::pruner::summarize`]. Masked layers report their
/// [`effective_channels`]; with an empty map this is exactly
/// `summarize`.
pub fn masked_summary(
    model: &Model,
    state: &PruneState,
    schemes: &SchemeMap,
    criterion: Criterion,
) -> PruneSummary {
    let convs = model.graph.conv_ids();
    let n = convs.len().max(1) as f64;
    let layers = convs
        .iter()
        .enumerate()
        .filter_map(|(pos, &id)| {
            let orig = match model.graph.node(id).op {
                OpKind::Conv2d { cout, .. } => cout,
                _ => return None,
            };
            let mut remaining = state.cout.get(&id).copied().unwrap_or(orig);
            if let Some(choice) = schemes.get(&id) {
                remaining = effective_channels(remaining, choice);
            }
            Some(LayerPrune {
                conv: id,
                original_channels: orig,
                remaining_channels: remaining,
                depth: (pos as f64 + 1.0) / n,
            })
        })
        .collect();
    PruneSummary { model: model.kind, layers, criterion }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::model_zoo::ModelKind;
    use crate::pruner::summarize;

    #[test]
    fn scheme_names_round_trip() {
        for s in Scheme::ALL {
            assert_eq!(Scheme::from_name(s.name()), Some(s));
        }
        assert_eq!(Scheme::from_name("vibes"), None);
    }

    #[test]
    fn choice_json_round_trips() {
        for s in Scheme::ALL {
            let c = SchemeChoice::for_scheme(s);
            let j = c.to_json();
            let back = SchemeChoice::from_json(&j).unwrap();
            assert_eq!(back, c);
            // canonical: parse(serialize(x)) serializes identically
            assert_eq!(back.to_json().to_string(), j.to_string());
        }
        let bad = Json::obj(vec![
            ("density", Json::Num(1.5)),
            ("scheme", Json::Str("pattern".to_string())),
        ]);
        assert!(SchemeChoice::from_json(&bad).is_err());
        let unknown = Json::obj(vec![
            ("density", Json::Num(0.5)),
            ("scheme", Json::Str("vibes".to_string())),
        ]);
        assert!(SchemeChoice::from_json(&unknown).is_err());
    }

    #[test]
    fn empty_scheme_map_matches_summarize_exactly() {
        let m = Model::build(ModelKind::ResNet8Cifar, 0);
        let mut st = PruneState::full(&m);
        st.shrink(m.prunable[0], 4);
        let dense = summarize(&m, &st, Criterion::L1Norm);
        let masked = masked_summary(&m, &st, &SchemeMap::new(), Criterion::L1Norm);
        assert_eq!(dense.layers.len(), masked.layers.len());
        for (a, b) in dense.layers.iter().zip(&masked.layers) {
            assert_eq!(a.conv, b.conv);
            assert_eq!(a.remaining_channels, b.remaining_channels);
            assert_eq!(a.depth, b.depth);
        }
    }

    #[test]
    fn masked_layers_report_fewer_effective_channels() {
        let m = Model::build(ModelKind::ResNet8Cifar, 0);
        let st = PruneState::full(&m);
        let conv = m.prunable[0];
        let mut schemes = SchemeMap::new();
        schemes.insert(conv, SchemeChoice::pattern());
        let s = masked_summary(&m, &st, &schemes, Criterion::L1Norm);
        let l = s.layers.iter().find(|l| l.conv == conv).unwrap();
        assert!(l.remaining_channels < l.original_channels);
        assert!(l.remaining_channels >= 2);
        // pattern retains more effective channels than block at its
        // (lower) density raised to the retention exponents
        let pat = effective_channels(64, &SchemeChoice::pattern());
        let blk = effective_channels(64, &SchemeChoice::block());
        assert!(pat > blk, "pattern {pat} should retain more than block {blk}");
        // channel choice is the identity
        assert_eq!(effective_channels(64, &SchemeChoice::channel()), 64);
    }

    #[test]
    fn effective_channels_floors_at_two() {
        assert_eq!(effective_channels(2, &SchemeChoice::block()), 2);
        assert_eq!(effective_channels(3, &SchemeChoice::pattern()), 2);
    }
}
