//! PatDNN-style kernel pattern library (DESIGN.md §16).
//!
//! Every 3×3 kernel of a pattern-sparse layer keeps the same number of
//! taps ([`KEPT_TAPS`] of [`TOTAL_TAPS`]), drawn from a small fixed
//! library — that regularity is what lets the compiler compact the
//! kernels and reorder filters so the sparse loop nest stays dense
//! inside (arXiv 2001.00138, §3). Each filter independently picks the
//! library pattern that retains the most ℓ1 mass, mirroring the
//! magnitude criterion the channel path uses
//! ([`crate::graph::weights::Weights::l1_norms`]).
//!
//! Tap indices address the 3×3 kernel row-major:
//!
//! ```text
//!   0 1 2
//!   3 4 5
//!   6 7 8
//! ```
//!
//! Every library pattern contains the center tap 4 — PatDNN's observed
//! property of trained kernels, and what keeps the scheme's accuracy
//! retention high (see `retention_exponent` in [`crate::sparsity`]).

use crate::graph::ops::OpKind;
use crate::graph::weights::Weights;

/// Taps each kernel keeps.
pub const KEPT_TAPS: usize = 4;
/// Taps of a 3×3 kernel.
pub const TOTAL_TAPS: usize = 9;
/// Weight density of a pattern-sparse layer.
pub const DENSITY: f64 = KEPT_TAPS as f64 / TOTAL_TAPS as f64;

/// The pattern library: 8 four-tap patterns, all containing the center
/// tap, covering the cross/corner shapes PatDNN's clustering finds.
pub const PATTERNS: [[usize; KEPT_TAPS]; 8] = [
    [1, 3, 4, 5],
    [1, 4, 5, 7],
    [3, 4, 5, 7],
    [1, 3, 4, 7],
    [0, 1, 3, 4],
    [1, 2, 4, 5],
    [3, 4, 6, 7],
    [4, 5, 7, 8],
];

/// Whether the scheme can lower this operator: plain (non-grouped) 3×3
/// convolutions only — the shape the pattern library is defined over.
pub fn applicable(op: &OpKind) -> bool {
    matches!(op, OpKind::Conv2d { kh: 3, kw: 3, groups: 1, .. })
}

/// Library index of the pattern retaining the most ℓ1 mass for one
/// flattened HWI filter (`cin_g` input channels per tap; tap `t` owns
/// `filter[t*cin_g .. (t+1)*cin_g]`). Ties break to the lowest index
/// for determinism.
pub fn best_pattern(filter: &[f32], cin_g: usize) -> usize {
    let mut best = 0usize;
    let mut best_mass = f32::NEG_INFINITY;
    for (i, taps) in PATTERNS.iter().enumerate() {
        let mass: f32 = taps
            .iter()
            .map(|&t| filter[t * cin_g..(t + 1) * cin_g].iter().map(|w| w.abs()).sum::<f32>())
            .sum();
        if mass.total_cmp(&best_mass) == std::cmp::Ordering::Greater {
            best = i;
            best_mass = mass;
        }
    }
    best
}

/// Per-filter pattern assignment for a conv's current weight bank:
/// `assignment[f]` is the library index filter `f` keeps. Empty when
/// the conv has no weights recorded.
pub fn assignment(weights: &Weights, conv: usize, cin_g: usize) -> Vec<usize> {
    weights
        .convs
        .get(&conv)
        .map(|filters| filters.iter().map(|f| best_pattern(f, cin_g)).collect())
        .unwrap_or_default()
}

/// Sorted, de-duplicated library indices an assignment uses — the
/// `params` of a pattern [`crate::sparsity::mask::LayerMask`].
pub fn used_patterns(assignment: &[usize]) -> Vec<usize> {
    let mut used: Vec<usize> = assignment.to_vec();
    used.sort_unstable();
    used.dedup();
    used
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ops::Graph;

    #[test]
    fn library_is_well_formed() {
        for taps in PATTERNS {
            assert!(taps.contains(&4), "pattern {taps:?} drops the center tap");
            assert!(taps.windows(2).all(|w| w[0] < w[1]), "unsorted {taps:?}");
            assert!(taps.iter().all(|&t| t < TOTAL_TAPS));
        }
        assert!((DENSITY - 4.0 / 9.0).abs() < 1e-12);
    }

    #[test]
    fn applicability_is_shape_driven() {
        let three = OpKind::Conv2d { kh: 3, kw: 3, cin: 16, cout: 16, stride: 1, padding: 1, groups: 1 };
        let one = OpKind::Conv2d { kh: 1, kw: 1, cin: 16, cout: 16, stride: 1, padding: 0, groups: 1 };
        let dw = OpKind::Conv2d { kh: 3, kw: 3, cin: 16, cout: 16, stride: 1, padding: 1, groups: 16 };
        assert!(applicable(&three));
        assert!(!applicable(&one));
        assert!(!applicable(&dw));
        assert!(!applicable(&OpKind::ReLU));
    }

    #[test]
    fn best_pattern_maximizes_retained_mass() {
        // cin_g = 1: the filter IS the 9-tap kernel. Put all mass on the
        // top row + center — pattern [0,1,3,4] (index 4) wins.
        let mut f = vec![0.0f32; 9];
        f[0] = 1.0;
        f[1] = 1.0;
        f[3] = 1.0;
        f[4] = 1.0;
        assert_eq!(best_pattern(&f, 1), 4);
        // bottom-right corner mass — pattern [4,5,7,8] (index 7) wins.
        let mut g = vec![0.0f32; 9];
        g[5] = 1.0;
        g[7] = 1.0;
        g[8] = 1.0;
        assert_eq!(best_pattern(&g, 1), 7);
        // all-equal mass ties: lowest library index wins.
        assert_eq!(best_pattern(&[1.0f32; 9], 1), 0);
    }

    #[test]
    fn assignment_is_deterministic_per_seed() {
        let mut g = Graph::new();
        let x = g.add("x", OpKind::Input { shape: [1, 8, 8, 4] }, vec![]);
        g.add(
            "c",
            OpKind::Conv2d { kh: 3, kw: 3, cin: 4, cout: 8, stride: 1, padding: 1, groups: 1 },
            vec![x],
        );
        let w1 = Weights::generate(&g, 7);
        let w2 = Weights::generate(&g, 7);
        let a1 = assignment(&w1, 1, 4);
        let a2 = assignment(&w2, 1, 4);
        assert_eq!(a1, a2);
        assert_eq!(a1.len(), 8);
        assert!(a1.iter().all(|&p| p < PATTERNS.len()));
        let used = used_patterns(&a1);
        assert!(used.windows(2).all(|w| w[0] < w[1]));
    }
}
