//! Mask-aware analytic latency (DESIGN.md §16).
//!
//! Prices a scheme assignment over an already-compiled task table: each
//! subgraph whose anchor conv is masked contributes its measured dense
//! latency times the device's
//! [`crate::device::sparse::scheme_factor`]; unmasked subgraphs
//! contribute it unchanged. This is what lets the selection loop
//! compare a mask candidate against a channel candidate *without
//! re-tuning* — the mask reuses the dense schedule (pattern compaction
//! and block skipping keep the loop structure; see
//! [`crate::tir::sparse::SparseLowering`]), so the dense measurement
//! plus the analytic factor is the candidate's latency.
//!
//! Float-exactness contract: with an empty scheme map this returns
//! *bit-for-bit* the compiled model's own latency
//! ([`crate::relay::TaskTable::model_latency`] plus the overhead term).
//! Each task sums its subgraph factors first and multiplies once —
//! all-dense factors sum to exactly the subgraph count, reproducing
//! `latency × count` — and tasks accumulate in table order. Tests pin
//! this with `==`.

use crate::device::sparse::scheme_factor;
use crate::device::spec::DeviceKind;
use crate::relay::partition::Partition;
use crate::relay::TaskTable;
use crate::sparsity::SchemeMap;

/// Masked latency of a compiled model (seconds): the task table's
/// per-subgraph latencies scaled by each anchor's scheme factor, plus
/// the graph-level overhead term.
pub fn masked_model_latency(
    part: &Partition,
    table: &TaskTable,
    overhead_latency: f64,
    kind: DeviceKind,
    schemes: &SchemeMap,
) -> f64 {
    let mut total = 0.0;
    for t in table.tasks() {
        let lat = t.best_latency.unwrap_or(0.0);
        let mut factor_sum = 0.0;
        for &sgid in &t.subgraphs {
            let anchor = part.subgraphs.get(sgid).map(|s| s.anchor);
            let factor = match anchor.and_then(|a| schemes.get(&a)) {
                Some(choice) => scheme_factor(kind, choice),
                None => 1.0,
            };
            factor_sum += factor;
        }
        total += lat * factor_sum;
    }
    total + overhead_latency
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler;
    use crate::device::{DeviceSpec, Simulator};
    use crate::graph::model_zoo::{Model, ModelKind};
    use crate::relay::partition::partition;
    use crate::sparsity::SchemeChoice;
    use crate::tuner::{TuneOptions, TuningSession};
    use std::collections::HashMap;

    #[test]
    fn empty_mask_reproduces_dense_latency_bitwise() {
        let m = Model::build(ModelKind::ResNet8Cifar, 0);
        let sim = Simulator::new(DeviceSpec::kryo385());
        let session = TuningSession::new(&sim, TuneOptions::quick(), 0);
        let compiled = compiler::compile_tuned(&m.graph, &session, &HashMap::new());
        let part = partition(&m.graph);
        let masked = masked_model_latency(
            &part,
            &compiled.table,
            compiled.overhead_latency,
            DeviceKind::Cpu,
            &SchemeMap::new(),
        );
        assert_eq!(masked, compiled.latency(), "dense pricing must be exact");
    }

    #[test]
    fn masking_an_anchor_strictly_lowers_latency() {
        let m = Model::build(ModelKind::ResNet8Cifar, 0);
        let sim = Simulator::new(DeviceSpec::kryo385());
        let session = TuningSession::new(&sim, TuneOptions::quick(), 0);
        let compiled = compiler::compile_tuned(&m.graph, &session, &HashMap::new());
        let part = partition(&m.graph);
        let dense = compiled.latency();
        let mut schemes = SchemeMap::new();
        schemes.insert(m.prunable[0], SchemeChoice::pattern());
        let masked = masked_model_latency(
            &part,
            &compiled.table,
            compiled.overhead_latency,
            DeviceKind::Cpu,
            &schemes,
        );
        assert!(masked < dense, "masked {masked} vs dense {dense}");
        // a channel "mask" prices as dense exactly
        let mut chan = SchemeMap::new();
        chan.insert(m.prunable[0], SchemeChoice::channel());
        let chan_lat = masked_model_latency(
            &part,
            &compiled.table,
            compiled.overhead_latency,
            DeviceKind::Cpu,
            &chan,
        );
        assert_eq!(chan_lat, dense);
    }

    #[test]
    fn gpu_and_cpu_price_the_same_mask_differently() {
        let m = Model::build(ModelKind::ResNet8Cifar, 0);
        let sim = Simulator::new(DeviceSpec::kryo385());
        let session = TuningSession::new(&sim, TuneOptions::quick(), 0);
        let compiled = compiler::compile_tuned(&m.graph, &session, &HashMap::new());
        let part = partition(&m.graph);
        let mut schemes = SchemeMap::new();
        schemes.insert(m.prunable[0], SchemeChoice::pattern());
        let cpu = masked_model_latency(
            &part,
            &compiled.table,
            compiled.overhead_latency,
            DeviceKind::Cpu,
            &schemes,
        );
        let gpu = masked_model_latency(
            &part,
            &compiled.table,
            compiled.overhead_latency,
            DeviceKind::Gpu,
            &schemes,
        );
        assert!(cpu < gpu, "pattern reorder must cost more on gpu: {cpu} vs {gpu}");
    }
}
