//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! Wraps the `xla` crate (xla_extension 0.5.1 via the PJRT C API). The
//! interchange format is HLO *text*: jax ≥ 0.5 emits serialized protos
//! with 64-bit instruction ids that this XLA rejects, while the text
//! parser reassigns ids (see /opt/xla-example/README.md). Python runs only
//! at build time (`make artifacts`); this module is the entire request
//! path.

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// A PJRT client + the artifact directory.
pub struct Runtime {
    client: xla::PjRtClient,
    artifact_dir: PathBuf,
}

/// One compiled executable (e.g. `train_step`, `eval_batch`).
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Runtime {
    /// CPU PJRT client rooted at an artifact directory.
    pub fn cpu(artifact_dir: impl AsRef<Path>) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, artifact_dir: artifact_dir.as_ref().to_path_buf() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile `<artifact_dir>/<name>.hlo.txt`.
    pub fn load(&self, name: &str) -> Result<Executable> {
        let path = self.artifact_dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exe, name: name.to_string() })
    }

    /// Path helper for non-HLO artifacts (manifest, params).
    pub fn artifact(&self, file: &str) -> PathBuf {
        self.artifact_dir.join(file)
    }
}

impl Executable {
    /// Execute with literal inputs; returns the flattened tuple outputs.
    /// (aot.py lowers with `return_tuple=True`, so the single output is
    /// always a tuple — even for one result.)
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.name))?;
        let out = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        out.to_tuple().context("decomposing output tuple")
    }
}

/// Build an f32 literal of the given shape from a slice.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    if dims.len() == 1 {
        return Ok(lit);
    }
    lit.reshape(dims).context("reshaping literal")
}

/// Build an i32 literal (labels).
pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    if dims.len() == 1 {
        return Ok(lit);
    }
    lit.reshape(dims).context("reshaping literal")
}

/// Scalar f32 literal.
pub fn literal_scalar(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Read a literal back to Vec<f32>.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().context("reading literal as f32")
}
