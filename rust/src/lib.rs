//! # CPrune — compiler-informed model pruning (reproduction)
//!
//! Three-layer architecture (see DESIGN.md):
//! * **L3 (this crate)** — the paper's contribution (the CPrune search,
//!   `pruner/`) plus every substrate it assumes: a DNN graph IR (`graph/`),
//!   a Relay-style partitioner (`relay/`), a TVM-style loop-nest IR and
//!   schedule space (`tir/`), an Ansor-style auto-tuner (`tuner/`), a
//!   mobile-device latency simulator behind the pluggable measurement
//!   plane (`device/`, DESIGN.md §11: one [`device::Target`] trait with
//!   analytic/LUT/record-replay providers and a name registry), baseline
//!   pruners
//!   (`baselines/`), accuracy oracles (`accuracy/`), the end-to-end
//!   compile pipeline (`compiler/`), the serving layer (`serve/`,
//!   DESIGN.md §8): the Pareto-set registry of deployable checkpoints
//!   each CPrune run now emits, and the deterministic serving simulator
//!   that dispatches SLO-bound traffic across a device fleet from those
//!   frontiers — and the run layer (`run/`, DESIGN.md §9): the uniform
//!   [`run::Pruner`] trait over CPrune and all five baselines, the
//!   fluent [`run::RunBuilder`] wiring (model/device/tuning/seed/cache/
//!   budget), and the typed [`run::RunEvent`] stream with JSONL, CLI
//!   progress and registry-publisher observers. Cross-cutting semantic
//!   checks live in `verify/` (DESIGN.md §13): one structured
//!   [`verify::Diagnostic`] vocabulary (`CPV1xx`) over graphs, schedules
//!   and persisted artifacts, enforced at mutation boundaries and by the
//!   `cprune check` CLI sweep in CI.
//! * **L2/L1 (python/, build-time only)** — JAX masked CNN + Pallas GEMM
//!   kernels, AOT-lowered to HLO text and executed from `runtime/` +
//!   `train/` via PJRT. Python never runs on the request path.
//!
//! The XLA/PJRT-dependent code (`runtime/`, `train::driver`) sits behind
//! the off-by-default `pjrt` cargo feature (DESIGN.md §6): the default
//! build is pure-Rust, offline and dependency-free.

pub mod accuracy;
pub mod baselines;
pub mod cli;
pub mod compiler;
pub mod device;
pub mod exp;
pub mod graph;
pub mod perf;
pub mod pruner;
pub mod relay;
pub mod run;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod serve;
pub mod sparsity;
pub mod tir;
pub mod train;
pub mod tuner;
pub mod util;
pub mod verify;
