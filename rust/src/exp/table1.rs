//! Table 1 — method comparison on ImageNet-scale models / mobile targets.
//!
//! Rows per (model, device): Original (TVM), PQF+TVM, FPGM+TVM,
//! NetAdapt+TVM, AMC+TVM, CPrune. Shape to reproduce: CPrune posts the
//! highest FPS increase rate (1.3–2.7×) at a top-1 within ~1.6 pp of the
//! original; NetAdapt is the closest runner-up; PQF barely moves CPU FPS.

use crate::accuracy::ProxyOracle;
use crate::baselines::amc::{amc, AmcConfig};
use crate::baselines::fpgm::fpgm_prune;
use crate::baselines::netadapt::{netadapt, NetAdaptConfig};
use crate::baselines::pqf::pqf;
use crate::baselines::{original_row, Outcome};
use crate::device::{DeviceSpec, Simulator};
use crate::exp::Scale;
use crate::graph::model_zoo::{Model, ModelKind};
use crate::graph::stats;
use crate::pruner::{cprune, CPruneConfig};
use crate::tuner::TuningSession;

#[derive(Debug)]
pub struct Table1Block {
    pub model: &'static str,
    pub device: &'static str,
    pub rows: Vec<Outcome>,
}

/// Which (model, device) cells to run; the paper's Table 1 set.
pub fn paper_cells() -> Vec<(ModelKind, DeviceSpec)> {
    vec![
        (ModelKind::ResNet18ImageNet, DeviceSpec::kryo385()),
        (ModelKind::ResNet18ImageNet, DeviceSpec::mali_g72()),
        (ModelKind::MobileNetV2ImageNet, DeviceSpec::kryo385()),
        (ModelKind::MobileNetV2ImageNet, DeviceSpec::mali_g72()),
        (ModelKind::MnasNet10ImageNet, DeviceSpec::kryo585()),
    ]
}

pub fn run_cell(kind: ModelKind, spec: DeviceSpec, scale: Scale, seed: u64) -> Table1Block {
    let model = Model::build(kind, seed);
    let device_name = spec.name;
    let sim = Simulator::new(spec);
    let session = TuningSession::new(&sim, scale.tune_opts(), seed);
    let mut oracle = ProxyOracle::new();

    let (orig, base_latency) = original_row(&model, &session);
    let mut rows = vec![orig];

    rows.push(pqf(&model, &session, &sim, base_latency));
    rows.push(fpgm_prune(&model, 0.25, &session, &mut oracle, base_latency));

    let na = netadapt(
        &model,
        &session,
        &sim,
        &mut oracle,
        &NetAdaptConfig {
            target_latency_ratio: 0.65,
            max_iterations: scale.cprune_iters().min(20),
            ..Default::default()
        },
    );
    rows.push(na.outcome);

    rows.push(amc(
        &model,
        &session,
        &mut oracle,
        &AmcConfig::default(),
        base_latency,
    ));

    let cp = cprune(
        &model,
        &sim,
        &mut ProxyOracle::new(),
        &CPruneConfig {
            max_iterations: scale.cprune_iters(),
            tune_opts: scale.tune_opts(),
            seed,
            target_accuracy: crate::exp::paper_accuracy_budget(kind),
            ..Default::default()
        },
    );
    let (flops, params) = stats::flops_params(&cp.final_graph);
    rows.push(Outcome {
        method: "CPrune".into(),
        fps: cp.final_fps,
        fps_increase_rate: cp.fps_increase_rate,
        macs: flops / 2,
        params,
        top1: cp.final_top1,
        top5: cp.final_top5,
        search_candidates: cp.candidates_tried,
        main_step_seconds: cp.main_step_seconds,
    });

    Table1Block { model: kind.name(), device: device_name, rows }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cprune_wins_the_resnet18_kryo385_cell() {
        let block = run_cell(
            ModelKind::ResNet18ImageNet,
            DeviceSpec::kryo385(),
            Scale::Smoke,
            7,
        );
        assert_eq!(block.rows.len(), 6);
        let fps_of = |m: &str| {
            block
                .rows
                .iter()
                .find(|r| r.method.contains(m))
                .map(|r| r.fps)
                .unwrap()
        };
        let cprune_fps = fps_of("CPrune");
        let orig_fps = fps_of("Original");
        let pqf_fps = fps_of("PQF");
        assert!(cprune_fps > orig_fps, "CPrune must beat Original");
        assert!(cprune_fps > pqf_fps, "CPrune must beat PQF on CPU");
        // accuracy stays within a few points of original
        let cp = block.rows.iter().find(|r| r.method == "CPrune").unwrap();
        assert!(cp.top1 > 0.6976 - 0.06);
        // pruned model is smaller
        let orig = &block.rows[0];
        assert!(cp.macs < orig.macs && cp.params < orig.params);
    }
}
