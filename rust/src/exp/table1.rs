//! Table 1 — method comparison on ImageNet-scale models / mobile targets.
//!
//! Rows per (model, device): Original (TVM), PQF+TVM, FPGM+TVM,
//! NetAdapt+TVM, AMC+TVM, CPrune. Shape to reproduce: CPrune posts the
//! highest FPS increase rate (1.3–2.7×) at a top-1 within ~1.6 pp of the
//! original; NetAdapt is the closest runner-up; PQF barely moves CPU FPS.
//!
//! Every method runs through the uniform [`Pruner`] trait on one shared
//! [`RunBuilder`] wiring — the per-cell loop has no per-algorithm
//! branches (DESIGN.md §9).

use crate::baselines::amc::AmcConfig;
use crate::baselines::netadapt::NetAdaptConfig;
use crate::baselines::Outcome;
use crate::device::DeviceSpec;
use crate::exp::Scale;
use crate::graph::model_zoo::ModelKind;
use crate::pruner::CPruneConfig;
use crate::run::{Amc, CPrune, Fpgm, NetAdapt, Pqf, Pruner, RunBuilder};

#[derive(Debug)]
pub struct Table1Block {
    pub model: &'static str,
    pub device: &'static str,
    pub rows: Vec<Outcome>,
}

/// Which (model, device) cells to run; the paper's Table 1 set.
pub fn paper_cells() -> Vec<(ModelKind, DeviceSpec)> {
    vec![
        (ModelKind::ResNet18ImageNet, DeviceSpec::kryo385()),
        (ModelKind::ResNet18ImageNet, DeviceSpec::mali_g72()),
        (ModelKind::MobileNetV2ImageNet, DeviceSpec::kryo385()),
        (ModelKind::MobileNetV2ImageNet, DeviceSpec::mali_g72()),
        (ModelKind::MnasNet10ImageNet, DeviceSpec::kryo585()),
    ]
}

/// The method lineup of one Table-1 cell, in row order.
fn methods(kind: ModelKind, scale: Scale, seed: u64) -> Vec<Box<dyn Pruner>> {
    vec![
        Box::new(Pqf),
        Box::new(Fpgm::at(0.25)),
        Box::new(NetAdapt::with(NetAdaptConfig {
            target_latency_ratio: 0.65,
            max_iterations: scale.cprune_iters().min(20),
            ..Default::default()
        })),
        Box::new(Amc::with(AmcConfig::default())),
        Box::new(CPrune::with_cfg(CPruneConfig {
            max_iterations: scale.cprune_iters(),
            tune_opts: scale.tune_opts(),
            seed,
            target_accuracy: crate::exp::paper_accuracy_budget(kind),
            ..Default::default()
        })),
    ]
}

pub fn run_cell(kind: ModelKind, spec: DeviceSpec, scale: Scale, seed: u64) -> Table1Block {
    let device_name = spec.name;
    let mut run = RunBuilder::new(kind)
        .device_spec(spec)
        .seed(seed)
        .tune_opts(scale.tune_opts())
        .build()
        .expect("zoo model + known device"); // cprune-lint: allow(CPL005, reason="experiment drivers abort loudly by design")

    let (orig, _) = run.original_row();
    let mut rows = vec![orig];
    for pruner in methods(kind, scale, seed) {
        let out = run.execute(pruner.as_ref()).expect("pruner run"); // cprune-lint: allow(CPL005, reason="experiment drivers abort loudly by design")
        rows.push(out.to_outcome());
    }

    Table1Block { model: kind.name(), device: device_name, rows }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cprune_wins_the_resnet18_kryo385_cell() {
        let block = run_cell(
            ModelKind::ResNet18ImageNet,
            DeviceSpec::kryo385(),
            Scale::Smoke,
            7,
        );
        assert_eq!(block.rows.len(), 6);
        let fps_of = |m: &str| {
            block
                .rows
                .iter()
                .find(|r| r.method.contains(m))
                .map(|r| r.fps)
                .unwrap()
        };
        let cprune_fps = fps_of("CPrune");
        let orig_fps = fps_of("Original");
        let pqf_fps = fps_of("PQF");
        assert!(cprune_fps > orig_fps, "CPrune must beat Original");
        assert!(cprune_fps > pqf_fps, "CPrune must beat PQF on CPU");
        // accuracy stays within a few points of original
        let cp = block.rows.iter().find(|r| r.method == "CPrune").unwrap();
        assert!(cp.top1 > 0.6976 - 0.06);
        // pruned model is smaller
        let orig = &block.rows[0];
        assert!(cp.macs < orig.macs && cp.params < orig.params);
    }
}
