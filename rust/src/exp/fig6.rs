//! Fig. 6 — FPS increase rate + short-term accuracy across CPrune's
//! iterations (ResNet-18, Kryo 385, ImageNet-scale).
//!
//! Paper shape: FPS rate climbs monotonically toward ~1.96×; short-term
//! accuracy decays gently; around iteration 6 the rate passes ~1.3× while
//! accuracy is still ≥ 89 % top-5-equivalent.

use crate::accuracy::ProxyOracle;
use crate::device::{DeviceSpec, Simulator};
use crate::exp::Scale;
use crate::graph::model_zoo::{Model, ModelKind};
use crate::pruner::{cprune, CPruneConfig, CPruneResult};

pub struct Fig6Result {
    pub result: CPruneResult,
    /// (iteration, fps_rate, short_top1) series.
    pub series: Vec<(usize, f64, f64)>,
}

pub fn run(scale: Scale, seed: u64) -> Fig6Result {
    let model = Model::build(ModelKind::ResNet18ImageNet, seed);
    let sim = Simulator::new(DeviceSpec::kryo385());
    let mut oracle = ProxyOracle::new();
    let cfg = CPruneConfig {
        max_iterations: scale.cprune_iters(),
        tune_opts: scale.tune_opts(),
        seed,
        target_accuracy: crate::exp::paper_accuracy_budget(ModelKind::ResNet18ImageNet),
        ..Default::default()
    };
    let result = cprune(&model, &sim, &mut oracle, &cfg);
    let series = result
        .iterations
        .iter()
        .map(|it| (it.iteration, it.fps_rate, it.short_accuracy))
        .collect();
    Fig6Result { result, series }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6_series_shape() {
        let r = run(Scale::Smoke, 1);
        assert!(!r.series.is_empty(), "CPrune accepted no iterations");
        // FPS rate is non-decreasing over iterations
        for w in r.series.windows(2) {
            assert!(w[1].1 >= w[0].1 * 0.999, "rate regressed: {w:?}");
        }
        // accuracy decays but stays near base
        for (_, _, acc) in &r.series {
            assert!(*acc > 0.55 && *acc <= 0.6976 + 1e-9);
        }
        assert!(r.result.fps_increase_rate > 1.1);
    }
}
