//! Fig. 6 — FPS increase rate + short-term accuracy across CPrune's
//! iterations (ResNet-18, Kryo 385, ImageNet-scale).
//!
//! Paper shape: FPS rate climbs monotonically toward ~1.96×; short-term
//! accuracy decays gently; around iteration 6 the rate passes ~1.3× while
//! accuracy is still ≥ 89 % top-5-equivalent.

use crate::exp::Scale;
use crate::graph::model_zoo::ModelKind;
use crate::pruner::CPruneConfig;
use crate::run::{CPrune, PruneOutcome, RunBuilder};

pub struct Fig6Result {
    pub outcome: PruneOutcome,
    /// (iteration, fps_rate, short_top1) series.
    pub series: Vec<(usize, f64, f64)>,
}

pub fn run(scale: Scale, seed: u64) -> Fig6Result {
    let kind = ModelKind::ResNet18ImageNet;
    let mut run = RunBuilder::new(kind)
        .device("kryo385")
        .seed(seed)
        .tune_opts(scale.tune_opts())
        .build()
        .expect("zoo model + known device"); // cprune-lint: allow(CPL005, reason="experiment drivers abort loudly by design")
    let cfg = CPruneConfig {
        max_iterations: scale.cprune_iters(),
        tune_opts: scale.tune_opts(),
        seed,
        target_accuracy: crate::exp::paper_accuracy_budget(kind),
        ..Default::default()
    };
    let outcome = run.execute(&CPrune::with_cfg(cfg)).expect("cprune run"); // cprune-lint: allow(CPL005, reason="experiment drivers abort loudly by design")
    let series = outcome
        .iterations
        .iter()
        .map(|it| (it.iteration, it.fps_rate, it.short_accuracy))
        .collect();
    Fig6Result { outcome, series }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6_series_shape() {
        let r = run(Scale::Smoke, 1);
        assert!(!r.series.is_empty(), "CPrune accepted no iterations");
        // FPS rate is non-decreasing over iterations
        for w in r.series.windows(2) {
            assert!(w[1].1 >= w[0].1 * 0.999, "rate regressed: {w:?}");
        }
        // accuracy decays but stays near base
        for (_, _, acc) in &r.series {
            assert!(*acc > 0.55 && *acc <= 0.6976 + 1e-9);
        }
        assert!(r.outcome.fps_increase_rate > 1.1);
    }
}
