//! Supplementary ablation: "the details of finding reasonable α and β
//! values" (§4.1 points to the paper's supplementary materials).
//!
//! Sweeps α (the per-iteration accuracy-retention gate) and β (the
//! latency-target ratio) over a grid and reports final FPS rate, final
//! accuracy and search cost for each cell — showing the trade-off the
//! paper's chosen values sit on: loose α over-prunes accuracy, tight α
//! stops early; β near 1 creeps (many candidates), small β overshoots
//! (few, aggressive steps that the accuracy gate then rejects).
//!
//! Every grid cell is one [`CPrune`] execution on a single shared
//! [`RunBuilder`] wiring (DESIGN.md §9) — the warm tune cache makes the
//! 12-cell sweep far cheaper than 12 cold searches.

use crate::exp::Scale;
use crate::graph::model_zoo::ModelKind;
use crate::pruner::CPruneConfig;
use crate::run::{CPrune, RunBuilder};

#[derive(Clone, Debug)]
pub struct AlphaBetaCell {
    pub alpha: f64,
    pub beta: f64,
    pub fps_rate: f64,
    pub final_top1: f64,
    pub iterations: usize,
    pub candidates: usize,
}

pub fn run(scale: Scale, seed: u64) -> Vec<AlphaBetaCell> {
    let alphas = [0.90, 0.95, 0.98, 0.995];
    let betas = [0.90, 0.97, 0.995];
    let mut run = RunBuilder::new(ModelKind::ResNet18Cifar)
        .device("kryo585")
        .seed(seed)
        .tune_opts(scale.tune_opts())
        .build()
        .expect("zoo model + known device"); // cprune-lint: allow(CPL005, reason="experiment drivers abort loudly by design")
    let mut out = Vec::new();
    for &alpha in &alphas {
        for &beta in &betas {
            let cfg = CPruneConfig {
                alpha,
                beta,
                max_iterations: scale.cprune_iters(),
                tune_opts: scale.tune_opts(),
                seed,
                target_accuracy: 0.90,
                ..Default::default()
            };
            let r = run.execute(&CPrune::with_cfg(cfg)).expect("sweep cell"); // cprune-lint: allow(CPL005, reason="experiment drivers abort loudly by design")
            out.push(AlphaBetaCell {
                alpha,
                beta,
                fps_rate: r.fps_increase_rate,
                final_top1: r.top1,
                iterations: r.iterations.len(),
                candidates: r.search_candidates,
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alpha_beta_tradeoffs_visible() {
        let cells = run(Scale::Smoke, 3);
        assert_eq!(cells.len(), 12);
        // looser alpha (0.90) must prune at least as deep as the tightest
        let rate_at = |a: f64, b: f64| {
            cells
                .iter()
                .find(|c| (c.alpha - a).abs() < 1e-9 && (c.beta - b).abs() < 1e-9)
                .unwrap()
                .fps_rate
        };
        assert!(rate_at(0.90, 0.97) >= rate_at(0.995, 0.97) * 0.95);
        // every cell produced a valid model
        for c in &cells {
            assert!(c.fps_rate >= 0.9, "{c:?}");
            assert!(c.final_top1 > 0.85);
        }
    }
}
