//! Schemes × devices comparison (DESIGN.md §16).
//!
//! Rows per (model, device): Original, CPrune (channel only), one-shot
//! pattern, one-shot block, CPrune+SchemeSelect. Shape to reproduce:
//! the selection loop never loses to the best single-scheme row at
//! equal seed/budget, and the cheapest non-channel scheme differs
//! between CPU (pattern-friendly) and GPU (block-friendly) targets —
//! the per-kind reorder costs in [`crate::device::sparse`] made
//! visible as a table.
//!
//! Every method runs through the uniform [`Pruner`] trait on one shared
//! [`RunBuilder`] wiring, exactly like `table1` (DESIGN.md §9).

use crate::baselines::Outcome;
use crate::device::DeviceSpec;
use crate::exp::Scale;
use crate::graph::model_zoo::ModelKind;
use crate::pruner::CPruneConfig;
use crate::run::{CPrune, Pruner, RunBuilder};
use crate::sparsity::{BlockPruner, PatternPruner, SchemeSelect};

#[derive(Debug)]
pub struct SchemeBlock {
    pub model: &'static str,
    pub device: &'static str,
    pub rows: Vec<Outcome>,
}

/// The (model, device) cells the scheme sweep runs: one CPU and one GPU
/// target so the device-dependent scheme ranking shows up side by side.
pub fn paper_cells() -> Vec<(ModelKind, DeviceSpec)> {
    vec![
        (ModelKind::ResNet8Cifar, DeviceSpec::kryo385()),
        (ModelKind::ResNet8Cifar, DeviceSpec::mali_g72()),
    ]
}

/// The method lineup of one cell, in row order. All four share the same
/// seed and iteration budget so the comparison is apples to apples.
fn methods(scale: Scale, seed: u64) -> Vec<Box<dyn Pruner>> {
    let cfg = CPruneConfig {
        max_iterations: scale.cprune_iters(),
        tune_opts: scale.tune_opts(),
        seed,
        ..Default::default()
    };
    let select = SchemeSelect::with_cfg(cfg.clone());
    vec![
        Box::new(CPrune::with_cfg(cfg)),
        Box::new(PatternPruner),
        Box::new(BlockPruner),
        Box::new(select),
    ]
}

pub fn run_cell(kind: ModelKind, spec: DeviceSpec, scale: Scale, seed: u64) -> SchemeBlock {
    let device_name = spec.name;
    let mut run = RunBuilder::new(kind)
        .device_spec(spec)
        .seed(seed)
        .tune_opts(scale.tune_opts())
        .build()
        .expect("zoo model + known device"); // cprune-lint: allow(CPL005, reason="experiment drivers abort loudly by design")

    let (orig, _) = run.original_row();
    let mut rows = vec![orig];
    for pruner in methods(scale, seed) {
        let out = run.execute(pruner.as_ref()).expect("pruner run"); // cprune-lint: allow(CPL005, reason="experiment drivers abort loudly by design")
        rows.push(out.to_outcome());
    }

    SchemeBlock { model: kind.name(), device: device_name, rows }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheme_select_never_loses_to_single_scheme_rows() {
        for (kind, spec) in paper_cells() {
            let device = spec.name;
            let block = run_cell(kind, spec, Scale::Smoke, 7);
            assert_eq!(block.rows.len(), 5, "{device}: row lineup changed");
            let lat_of = |m: &str| {
                block
                    .rows
                    .iter()
                    .find(|r| r.method == m)
                    .map(|r| 1.0 / r.fps)
                    .unwrap()
            };
            let select = lat_of("CPrune+SchemeSelect");
            for single in ["CPrune", "PatDNN(4-of-9)", "Block(2:4)"] {
                assert!(
                    select <= lat_of(single) * (1.0 + 1e-12),
                    "{device}: scheme-select lost to {single}"
                );
            }
        }
    }
}
