//! Fig. 8 — target-processor specificity: a CPrune model tuned for device
//! X runs fastest on X; executing it (with X's programs) on another
//! processor Y loses most of the gain.

use crate::accuracy::ProxyOracle;
use crate::compiler;
use crate::device::{DeviceSpec, Simulator};
use crate::exp::Scale;
use crate::graph::model_zoo::{Model, ModelKind};
use crate::pruner::{cprune, CPruneConfig};

#[derive(Clone, Debug)]
pub struct Fig8Row {
    pub tuned_for: &'static str,
    pub run_on: &'static str,
    pub fps: f64,
    /// FPS relative to running natively on `run_on` with its own programs.
    pub relative_to_native: f64,
}

pub fn run(scale: Scale, seed: u64) -> Vec<Fig8Row> {
    let devices = [DeviceSpec::kryo385(), DeviceSpec::kryo585(), DeviceSpec::mali_g72()];
    let model = Model::build(ModelKind::MobileNetV2ImageNet, seed);

    // CPrune per device: (final graph, final table) tuned natively.
    let results: Vec<_> = devices
        .iter()
        .map(|spec| {
            let sim = Simulator::new(spec.clone());
            let mut oracle = ProxyOracle::new();
            let cfg = CPruneConfig {
                max_iterations: scale.cprune_iters(),
                tune_opts: scale.tune_opts(),
                seed,
                target_accuracy: crate::exp::paper_accuracy_budget(ModelKind::MobileNetV2ImageNet),
                ..Default::default()
            };
            cprune(&model, &sim, &mut oracle, &cfg)
        })
        .collect();

    let mut rows = Vec::new();
    for (i, from) in devices.iter().enumerate() {
        for (j, to) in devices.iter().enumerate() {
            let sim_to = Simulator::new(to.clone());
            // run model i (its graph + its tuned programs) on device j
            let lat = compiler::latency_with_programs(
                &results[i].final_graph,
                &results[i].final_table,
                &sim_to,
            );
            let native = results[j].final_latency;
            rows.push(Fig8Row {
                tuned_for: from.name,
                run_on: to.name,
                fps: 1.0 / lat,
                relative_to_native: native / lat,
            });
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_beats_cross_device() {
        let rows = run(Scale::Smoke, 2);
        assert_eq!(rows.len(), 9);
        // diagonal (native) cells re-run the same programs; they differ
        // from the recorded latency only by measurement noise
        for r in &rows {
            if r.tuned_for == r.run_on {
                assert!(
                    (r.relative_to_native - 1.0).abs() < 0.08,
                    "diagonal cell off: {r:?}"
                );
            }
        }
        // every off-diagonal cell is at most native speed (allowing noise)
        let off: Vec<&Fig8Row> = rows.iter().filter(|r| r.tuned_for != r.run_on).collect();
        let worse = off.iter().filter(|r| r.relative_to_native < 0.999).count();
        assert!(
            worse * 3 >= off.len(),
            "cross-device execution should usually lose: {rows:?}"
        );
    }
}
