//! Fig. 8 — target-processor specificity: a CPrune model tuned for device
//! X runs fastest on X; executing it (with X's programs) on another
//! processor Y loses most of the gain.
//!
//! Built on the fleet layer: a [`FleetSession`] owns the device set, and
//! its `transfer_matrix` produces the tuned-for × run-on grid. The
//! per-device searches run through [`CPrune::run_full`] — the one caller
//! that needs the full [`crate::pruner::CPruneResult`] (final graph *and*
//! tuned task table) rather than the uniform outcome, because the
//! transfer matrix replays each device's tuned programs elsewhere.

use crate::accuracy::ProxyOracle;
use crate::device::DeviceSpec;
use crate::exp::Scale;
use crate::graph::model_zoo::{Model, ModelKind};
use crate::graph::ops::Graph;
use crate::pruner::CPruneConfig;
use crate::relay::TaskTable;
use crate::run::{CPrune, RunContext};
use crate::tuner::{FleetOptions, FleetSession, TuningSession};

#[derive(Clone, Debug)]
pub struct Fig8Row {
    pub tuned_for: &'static str,
    pub run_on: &'static str,
    pub fps: f64,
    /// FPS relative to running natively on `run_on` with its own programs.
    pub relative_to_native: f64,
}

pub fn run(scale: Scale, seed: u64) -> Vec<Fig8Row> {
    let specs = vec![DeviceSpec::kryo385(), DeviceSpec::kryo585(), DeviceSpec::mali_g72()];
    let model = Model::build(ModelKind::MobileNetV2ImageNet, seed);
    // The fleet only provides the device set + transfer grid here; tuning
    // budgets come from each run's session below, so the fleet's own tune
    // options are irrelevant.
    let fleet = FleetSession::new(specs, FleetOptions::default(), seed);
    let n = fleet.num_devices();

    // CPrune per device: (final graph, final table) tuned natively.
    let cfg = CPruneConfig {
        max_iterations: scale.cprune_iters(),
        tune_opts: scale.tune_opts(),
        seed,
        target_accuracy: crate::exp::paper_accuracy_budget(ModelKind::MobileNetV2ImageNet),
        ..Default::default()
    };
    let pruner = CPrune::with_cfg(cfg.clone());
    let results: Vec<_> = (0..n)
        .map(|i| {
            let session = TuningSession::new(fleet.target(i), cfg.tune_opts, seed);
            let mut oracle = ProxyOracle::new();
            let mut ctx = RunContext::standalone(&model, &session, &mut oracle);
            pruner.run_full(&mut ctx)
        })
        .collect();

    // Run model i (its graph + its tuned programs) on every device j.
    let models: Vec<(&Graph, &TaskTable)> = results
        .iter()
        .map(|r| (&r.final_graph, &r.final_table))
        .collect();
    fleet
        .transfer_matrix(&models)
        .into_iter()
        .enumerate()
        .map(|(idx, cell)| {
            let native = results[idx % n].final_latency;
            Fig8Row {
                tuned_for: cell.tuned_for,
                run_on: cell.run_on,
                fps: 1.0 / cell.latency,
                relative_to_native: native / cell.latency,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_beats_cross_device() {
        let rows = run(Scale::Smoke, 2);
        assert_eq!(rows.len(), 9);
        // diagonal (native) cells re-run the same programs; they differ
        // from the recorded latency only by measurement noise
        for r in &rows {
            if r.tuned_for == r.run_on {
                assert!(
                    (r.relative_to_native - 1.0).abs() < 0.08,
                    "diagonal cell off: {r:?}"
                );
            }
        }
        // every off-diagonal cell is at most native speed (allowing noise)
        let off: Vec<&Fig8Row> = rows.iter().filter(|r| r.tuned_for != r.run_on).collect();
        let worse = off.iter().filter(|r| r.relative_to_native < 0.999).count();
        assert!(
            worse * 3 >= off.len(),
            "cross-device execution should usually lose: {rows:?}"
        );
    }
}
