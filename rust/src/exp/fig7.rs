//! Fig. 7 — CPrune+TVM vs TVM-only vs target-agnostic library (TFLite).
//!
//! {ResNet-18, MobileNetV2} × {Kryo 385, Kryo 585, Mali-G72}: per cell,
//! FPS of (a) library-default schedules, (b) auto-tuned original model,
//! (c) CPrune's pruned+tuned model. Paper shape: (c) > (b) > (a), with
//! (c)/(b) between ~1.3× and ~2.7×.
//!
//! One [`RunBuilder`] per cell: (a) is a fallback compile of the run's
//! model, (b) the run's original row, (c) the CPrune execution — no
//! hand-wired session/oracle plumbing (DESIGN.md §9).

use crate::compiler;
use crate::device::DeviceSpec;
use crate::exp::Scale;
use crate::graph::model_zoo::ModelKind;
use crate::pruner::CPruneConfig;
use crate::run::{CPrune, RunBuilder};

#[derive(Clone, Debug)]
pub struct Fig7Row {
    pub model: &'static str,
    pub device: &'static str,
    pub fps_tflite: f64,
    pub fps_tvm: f64,
    pub fps_cprune: f64,
}

pub fn run(scale: Scale, seed: u64) -> Vec<Fig7Row> {
    let cells: Vec<(ModelKind, DeviceSpec)> = vec![
        (ModelKind::ResNet18ImageNet, DeviceSpec::kryo385()),
        (ModelKind::ResNet18ImageNet, DeviceSpec::mali_g72()),
        (ModelKind::MobileNetV2ImageNet, DeviceSpec::kryo385()),
        (ModelKind::MobileNetV2ImageNet, DeviceSpec::kryo585()),
        (ModelKind::MobileNetV2ImageNet, DeviceSpec::mali_g72()),
    ];
    cells
        .into_iter()
        .map(|(kind, spec)| {
            let device_name = spec.name;
            let mut run = RunBuilder::new(kind)
                .device_spec(spec)
                .seed(seed)
                .tune_opts(scale.tune_opts())
                .build()
                .expect("zoo model + known device"); // cprune-lint: allow(CPL005, reason="experiment drivers abort loudly by design")
            let fps_tflite = compiler::compile_fallback(&run.model.graph, run.target()).fps();
            let (orig, _) = run.original_row();
            let cfg = CPruneConfig {
                max_iterations: scale.cprune_iters(),
                tune_opts: scale.tune_opts(),
                seed,
                target_accuracy: crate::exp::paper_accuracy_budget(kind),
                ..Default::default()
            };
            let res = run.execute(&CPrune::with_cfg(cfg)).expect("cprune run"); // cprune-lint: allow(CPL005, reason="experiment drivers abort loudly by design")
            Fig7Row {
                model: kind.name(),
                device: device_name,
                fps_tflite,
                fps_tvm: orig.fps,
                fps_cprune: res.final_fps,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig7_ordering_holds_per_cell() {
        // One smoke cell is enough for the unit test; the bench does all.
        let mut run = RunBuilder::new(ModelKind::ResNet18ImageNet)
            .device("kryo385")
            .seed(1)
            .build()
            .unwrap();
        let tflite = compiler::compile_fallback(&run.model.graph, run.target()).fps();
        let (orig, _) = run.original_row();
        let tvm = orig.fps;
        assert!(tvm > tflite, "tuned {tvm} <= library {tflite}");
        let cfg = CPruneConfig {
            max_iterations: 6,
            tune_opts: Scale::Smoke.tune_opts(),
            seed: 1,
            ..Default::default()
        };
        let res = run.execute(&CPrune::with_cfg(cfg)).unwrap();
        assert!(res.final_fps > tvm * 0.98, "cprune {} vs tvm {tvm}", res.final_fps);
    }
}
