//! Fig. 7 — CPrune+TVM vs TVM-only vs target-agnostic library (TFLite).
//!
//! {ResNet-18, MobileNetV2} × {Kryo 385, Kryo 585, Mali-G72}: per cell,
//! FPS of (a) library-default schedules, (b) auto-tuned original model,
//! (c) CPrune's pruned+tuned model. Paper shape: (c) > (b) > (a), with
//! (c)/(b) between ~1.3× and ~2.7×.

use crate::accuracy::ProxyOracle;
use crate::compiler;
use crate::device::{DeviceSpec, Simulator};
use crate::exp::Scale;
use crate::graph::model_zoo::{Model, ModelKind};
use crate::pruner::{cprune, CPruneConfig};
use crate::tuner::TuningSession;
use std::collections::HashMap;

#[derive(Clone, Debug)]
pub struct Fig7Row {
    pub model: &'static str,
    pub device: &'static str,
    pub fps_tflite: f64,
    pub fps_tvm: f64,
    pub fps_cprune: f64,
}

pub fn run(scale: Scale, seed: u64) -> Vec<Fig7Row> {
    let cells: Vec<(ModelKind, DeviceSpec)> = vec![
        (ModelKind::ResNet18ImageNet, DeviceSpec::kryo385()),
        (ModelKind::ResNet18ImageNet, DeviceSpec::mali_g72()),
        (ModelKind::MobileNetV2ImageNet, DeviceSpec::kryo385()),
        (ModelKind::MobileNetV2ImageNet, DeviceSpec::kryo585()),
        (ModelKind::MobileNetV2ImageNet, DeviceSpec::mali_g72()),
    ];
    cells
        .into_iter()
        .map(|(kind, spec)| {
            let model = Model::build(kind, seed);
            let device_name = spec.name;
            let sim = Simulator::new(spec);
            let session = TuningSession::new(&sim, scale.tune_opts(), seed);
            let fps_tflite = compiler::compile_fallback(&model.graph, &sim).fps();
            let fps_tvm = compiler::compile_tuned(&model.graph, &session, &HashMap::new()).fps();
            let mut oracle = ProxyOracle::new();
            let cfg = CPruneConfig {
                max_iterations: scale.cprune_iters(),
                tune_opts: scale.tune_opts(),
                seed,
                target_accuracy: crate::exp::paper_accuracy_budget(kind),
                ..Default::default()
            };
            let res = cprune(&model, &sim, &mut oracle, &cfg);
            Fig7Row {
                model: kind.name(),
                device: device_name,
                fps_tflite,
                fps_tvm,
                fps_cprune: res.final_fps,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig7_ordering_holds_per_cell() {
        // One smoke cell is enough for the unit test; the bench does all.
        let model = Model::build(ModelKind::ResNet18ImageNet, 1);
        let sim = Simulator::new(DeviceSpec::kryo385());
        let session = TuningSession::new(&sim, Scale::Smoke.tune_opts(), 1);
        let tflite = compiler::compile_fallback(&model.graph, &sim).fps();
        let tvm = compiler::compile_tuned(&model.graph, &session, &HashMap::new()).fps();
        assert!(tvm > tflite, "tuned {tvm} <= library {tflite}");
        let mut oracle = ProxyOracle::new();
        let cfg = CPruneConfig {
            max_iterations: 6,
            tune_opts: Scale::Smoke.tune_opts(),
            seed: 1,
            ..Default::default()
        };
        let res = cprune(&model, &sim, &mut oracle, &cfg);
        assert!(res.final_fps > tvm * 0.98, "cprune {} vs tvm {tvm}", res.final_fps);
    }
}
