//! Experiment harnesses: one module per paper table/figure, plus the
//! north-star serving sweep (`serving`, DESIGN.md §8).
//!
//! Each harness is a pure function returning structured rows, shared by
//! the `rust/benches/*` regenerators (which print the table/series) and
//! the `examples/` binaries. DESIGN.md §4 maps experiment ↔ module ↔
//! bench target; EXPERIMENTS.md records paper-vs-measured.
//!
//! Harnesses build their wiring through the run layer (DESIGN.md §9):
//! a [`crate::run::RunBuilder`] per (model, device) cell and a loop over
//! [`crate::run::Pruner`] implementations instead of per-algorithm
//! plumbing — `table1`/`table2` compare methods through the one trait,
//! `serving` auto-publishes frontiers via
//! [`crate::run::RegistryPublisher`], and `fig8` uses
//! [`crate::run::CPrune::run_full`] where the transfer matrix needs the
//! full task table.

pub mod ablation_alpha_beta;
pub mod fig1;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig9_10;
pub mod fig11;
pub mod schemes;
pub mod serving;
pub mod table1;
pub mod table2;

use crate::device::DeviceSpec;

/// Effort scale for harnesses (benches default to `Full`; unit tests and
/// smoke runs use `Smoke` to stay fast).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    Smoke,
    Full,
}

impl Scale {
    pub fn tune_opts(&self) -> crate::tuner::TuneOptions {
        match self {
            Scale::Smoke => crate::tuner::TuneOptions::quick(),
            Scale::Full => crate::tuner::TuneOptions::default(),
        }
    }

    pub fn cprune_iters(&self) -> usize {
        match self {
            Scale::Smoke => 8,
            Scale::Full => 40,
        }
    }
}

/// The short-term-accuracy budget a_g implied by each paper experiment
/// (the paper's users "provide the accuracy requirement"; these values
/// make the search stop where the paper's final accuracies landed).
pub fn paper_accuracy_budget(kind: crate::graph::model_zoo::ModelKind) -> f64 {
    use crate::graph::model_zoo::ModelKind::*;
    match kind {
        ResNet18ImageNet => 0.670,
        ResNet34ImageNet => 0.710,
        MobileNetV1ImageNet => 0.685,
        MobileNetV2ImageNet => 0.695,
        MnasNet10ImageNet => 0.715,
        ResNet18Cifar => 0.922,
        Vgg16Cifar => 0.9280,
        ResNet8Cifar => 0.0,
    }
}

/// Short built-in device names (CLI help text; the authoritative list —
/// device files included — is `TargetRegistry::names`).
pub const DEVICE_NAMES: &str = "kryo280 kryo385 kryo585 mali-g72 rtx3080";

/// Non-panicking lookup for user-supplied device names. A thin shim over
/// the built-in [`crate::device::TargetRegistry`] (experiment harnesses
/// only ever name the paper's devices; CLI paths carry their own
/// registry with `--device-file` entries).
pub fn try_device_by_name(name: &str) -> Option<DeviceSpec> {
    crate::device::TargetRegistry::builtin().spec(name).cloned()
}

/// The devices of the paper's tables, by short name. Panics on unknown
/// names — experiment harnesses pass literals; CLI paths should use
/// [`try_device_by_name`].
pub fn device_by_name(name: &str) -> DeviceSpec {
    try_device_by_name(name).unwrap_or_else(|| panic!("unknown device {name}"))
}
