//! Figs. 9 & 10 — ablations on ResNet-18 / Kryo 585 / CIFAR-10.
//!
//! Fig. 9: associated-subgraphs pruning vs single-subgraph pruning —
//! relative Main-step time cost and final FPS (+accuracy, Table 2).
//! Fig. 10: with vs without tuning during the Main step — final FPS gap.
//!
//! The three variants are just differently configured [`CPrune`] pruners
//! looped over one [`RunBuilder`] wiring (DESIGN.md §9).

use crate::exp::Scale;
use crate::graph::model_zoo::ModelKind;
use crate::pruner::CPruneConfig;
use crate::run::{CPrune, PruneOutcome, RunBuilder};

#[derive(Debug)]
pub struct AblationRow {
    pub variant: &'static str,
    pub fps: f64,
    pub fps_increase_rate: f64,
    pub top1: f64,
    pub main_step_seconds: f64,
    pub candidates_tried: usize,
}

fn row(variant: &'static str, r: &PruneOutcome) -> AblationRow {
    AblationRow {
        variant,
        fps: r.final_fps,
        fps_increase_rate: r.fps_increase_rate,
        top1: r.top1,
        main_step_seconds: r.main_step_seconds,
        candidates_tried: r.search_candidates,
    }
}

pub fn run(scale: Scale, seed: u64) -> Vec<AblationRow> {
    // Fixed search effort: Fig. 9 compares strategies at equal budget.
    let budget = match scale {
        Scale::Smoke => 25,
        Scale::Full => 60,
    };
    let base_cfg = CPruneConfig {
        max_iterations: scale.cprune_iters(),
        tune_opts: scale.tune_opts(),
        seed,
        target_accuracy: crate::exp::paper_accuracy_budget(ModelKind::ResNet18Cifar),
        max_candidates: budget,
        ..Default::default()
    };
    let variants: [(&'static str, CPruneConfig); 3] = [
        ("CPrune", base_cfg.clone()),
        (
            "CPrune (single subgraph)",
            CPruneConfig { associated_subgraphs: false, ..base_cfg.clone() },
        ),
        (
            "CPrune (w/o tuning)",
            CPruneConfig { with_tuning: false, ..base_cfg },
        ),
    ];

    let mut run = RunBuilder::new(ModelKind::ResNet18Cifar)
        .device("kryo585")
        .seed(seed)
        .tune_opts(scale.tune_opts())
        .build()
        .expect("zoo model + known device"); // cprune-lint: allow(CPL005, reason="experiment drivers abort loudly by design")
    variants
        .into_iter()
        .map(|(variant, cfg)| {
            let out = run.execute(&CPrune::with_cfg(cfg)).expect("ablation run"); // cprune-lint: allow(CPL005, reason="experiment drivers abort loudly by design")
            row(variant, &out)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablations_shape() {
        let rows = run(Scale::Smoke, 4);
        assert_eq!(rows.len(), 3);
        let by = |v: &str| rows.iter().find(|r| r.variant.contains(v)).unwrap();
        let full = by("CPrune");
        let single = by("single");
        // Fig. 9: associated pruning reaches at least single-subgraph FPS
        // (usually higher) without losing meaningful accuracy.
        assert!(full.fps >= single.fps * 0.9);
        assert!((full.top1 - single.top1).abs() < 0.05);
        // all variants produce a valid speedup
        for r in &rows {
            assert!(r.fps_increase_rate >= 0.95, "{r:?}");
        }
    }
}
