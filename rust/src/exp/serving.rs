//! Serving experiment: the throughput-vs-SLO grid (DESIGN.md §8).
//!
//! Not a paper figure — this is the first north-star experiment: build
//! the per-device Pareto frontiers once (one CPrune run per device),
//! then sweep request rate × latency SLO through the serving simulator
//! and report what each operating point costs in tail latency, SLO
//! violations and served accuracy. The `serving` bench regenerates the
//! table.

use super::Scale;
use crate::device::DeviceSpec;
use crate::graph::model_zoo::ModelKind;
use crate::run::{CPrune, RegistryPublisher, RunBuilder};
use crate::serve::{Registry, ServeOptions, Simulator as ServeSimulator};
use std::cell::RefCell;
use std::rc::Rc;

/// One (rps, SLO) operating point of the sweep.
#[derive(Clone, Debug)]
pub struct ServingRow {
    pub rps: f64,
    pub slo_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub throughput_rps: f64,
    pub violation_rate: f64,
    pub served_accuracy: f64,
    /// Fraction of requests served below the preferred accuracy point.
    pub degraded_frac: f64,
}

impl ServingRow {
    /// Column headers matching [`ServingRow::table_row`].
    pub const TABLE_HEADERS: [&'static str; 9] = [
        "rps", "SLO ms", "p50 ms", "p95 ms", "p99 ms", "tput rps", "viol %", "acc", "degr %",
    ];

    pub fn table_row(&self) -> Vec<String> {
        vec![
            format!("{:.0}", self.rps),
            format!("{:.0}", self.slo_ms),
            format!("{:.2}", self.p50_ms),
            format!("{:.2}", self.p95_ms),
            format!("{:.2}", self.p99_ms),
            format!("{:.1}", self.throughput_rps),
            format!("{:.2}", self.violation_rate * 100.0),
            format!("{:.4}", self.served_accuracy),
            format!("{:.2}", self.degraded_frac * 100.0),
        ]
    }
}

/// The devices the sweep serves across.
pub fn device_set(scale: Scale) -> Vec<DeviceSpec> {
    match scale {
        Scale::Smoke => vec![DeviceSpec::kryo385(), DeviceSpec::kryo585()],
        Scale::Full => DeviceSpec::mobile_targets(),
    }
}

/// One CPrune run per device, frontiers auto-published to a shared
/// registry by the [`RegistryPublisher`] observer as each checkpoint is
/// emitted (DESIGN.md §9) — the frontier is servable while the searches
/// are still running, not just after.
pub fn build_registry(scale: Scale, seed: u64) -> (Registry, &'static str) {
    let kind = ModelKind::ResNet8Cifar;
    let shared = Rc::new(RefCell::new(Registry::new()));
    for spec in device_set(scale) {
        let device_name = spec.name;
        let mut run = RunBuilder::new(kind)
            .device_spec(spec)
            .seed(seed)
            .tune_opts(scale.tune_opts())
            .max_iterations(scale.cprune_iters())
            .observer(Box::new(RegistryPublisher::shared(
                shared.clone(),
                kind.name(),
                device_name,
            )))
            .build()
            .expect("zoo model + known device"); // cprune-lint: allow(CPL005, reason="experiment drivers abort loudly by design")
        run.execute(&CPrune::default()).expect("cprune run"); // cprune-lint: allow(CPL005, reason="experiment drivers abort loudly by design")
    }
    let registry = Rc::try_unwrap(shared)
        .expect("publishers dropped with their runs") // cprune-lint: allow(CPL005, reason="experiment drivers abort loudly by design")
        .into_inner();
    (registry, kind.name())
}

/// Sweep request rate × SLO against the registry's frontiers.
pub fn run(scale: Scale, seed: u64) -> Vec<ServingRow> {
    let (registry, model_name) = build_registry(scale, seed);
    let specs = device_set(scale);
    // A floor just under the best frontier accuracy: the policy prefers
    // the most accurate deployable point and has room to degrade.
    let floor = registry
        .entries()
        .filter_map(|(_, _, set)| set.most_accurate().map(|c| c.accuracy))
        .fold(f64::INFINITY, f64::min)
        * 0.995;
    let (rps_list, slo_list, requests) = match scale {
        Scale::Smoke => (vec![50.0, 200.0], vec![20.0, 60.0], 600),
        Scale::Full => (
            vec![25.0, 50.0, 100.0, 200.0, 400.0],
            vec![10.0, 25.0, 50.0, 100.0],
            4000,
        ),
    };
    let mut rows = Vec::with_capacity(rps_list.len() * slo_list.len());
    for &slo_ms in &slo_list {
        for &rps in &rps_list {
            let opts = ServeOptions {
                rps,
                requests,
                slo_ms,
                accuracy_floor: floor,
                trace_seed: seed,
                max_batch: 8,
            };
            let mut sim = ServeSimulator::new(opts);
            for spec in &specs {
                let set = registry
                    .get(model_name, spec.name)
                    .expect("build_registry covers every device"); // cprune-lint: allow(CPL005, reason="experiment drivers abort loudly by design")
                sim.add_device(spec.name, set).expect("frontier is non-empty"); // cprune-lint: allow(CPL005, reason="experiment drivers abort loudly by design")
            }
            let r = sim.run().expect("simulator has lanes"); // cprune-lint: allow(CPL005, reason="experiment drivers abort loudly by design")
            rows.push(ServingRow {
                rps,
                slo_ms,
                p50_ms: r.p50_ms,
                p95_ms: r.p95_ms,
                p99_ms: r.p99_ms,
                throughput_rps: r.throughput_rps,
                violation_rate: r.violation_rate,
                served_accuracy: r.mean_served_accuracy,
                degraded_frac: r.degraded_requests as f64 / r.requests as f64,
            });
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_sweep_produces_sane_rows() {
        let rows = run(Scale::Smoke, 0);
        assert_eq!(rows.len(), 4);
        for r in &rows {
            assert!(r.p50_ms > 0.0 && r.p50_ms.is_finite());
            assert!(r.p50_ms <= r.p95_ms && r.p95_ms <= r.p99_ms);
            assert!(r.throughput_rps > 0.0 && r.throughput_rps.is_finite());
            assert!((0.0..=1.0).contains(&r.violation_rate));
            assert!((0.0..=1.0).contains(&r.degraded_frac));
            assert!(r.served_accuracy > 0.0 && r.served_accuracy <= 1.0);
        }
        // the sweep is deterministic end-to-end
        let again = run(Scale::Smoke, 0);
        for (a, b) in rows.iter().zip(&again) {
            assert_eq!(a.table_row(), b.table_row());
            assert_eq!(a.p99_ms, b.p99_ms);
            assert_eq!(a.violation_rate, b.violation_rate);
        }
    }
}
