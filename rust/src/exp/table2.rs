//! Table 2 — ResNet-18 / CIFAR-10 on mobile CPUs: CPrune and its
//! ablations.
//!
//! Paper shape: Kryo 280 → 3.24×, Kryo 585 → 2.31×; w/o tuning only
//! 1.43×; single-subgraph pruning 1.97× — with top-1 within ~0.7 pp of
//! the 94.37 % original.

use crate::accuracy::ProxyOracle;
use crate::baselines::{original_row, Outcome};
use crate::device::{DeviceSpec, Simulator};
use crate::exp::Scale;
use crate::graph::model_zoo::{Model, ModelKind};
use crate::graph::stats;
use crate::pruner::{cprune, CPruneConfig, CPruneResult};
use crate::tuner::TuningSession;

#[derive(Debug)]
pub struct Table2Block {
    pub device: &'static str,
    pub rows: Vec<Outcome>,
}

fn outcome_of(method: &str, cp: &CPruneResult) -> Outcome {
    let (flops, params) = stats::flops_params(&cp.final_graph);
    Outcome {
        method: method.into(),
        fps: cp.final_fps,
        fps_increase_rate: cp.fps_increase_rate,
        macs: flops / 2,
        params,
        top1: cp.final_top1,
        top5: cp.final_top5,
        search_candidates: cp.candidates_tried,
        main_step_seconds: cp.main_step_seconds,
    }
}

pub fn run(scale: Scale, seed: u64) -> Vec<Table2Block> {
    let model = Model::build(ModelKind::ResNet18Cifar, seed);
    let mut blocks = Vec::new();

    // Kryo 280: plain CPrune row.
    {
        let sim = Simulator::new(DeviceSpec::kryo280());
        let session = TuningSession::new(&sim, scale.tune_opts(), seed);
        let (orig, _) = original_row(&model, &session);
        let cfg = CPruneConfig {
            max_iterations: scale.cprune_iters(),
            tune_opts: scale.tune_opts(),
            seed,
            // CIFAR tolerates deep pruning (paper prunes to 29% of MACs)
            alpha: 0.97,
            target_accuracy: crate::exp::paper_accuracy_budget(ModelKind::ResNet18Cifar),
            ..Default::default()
        };
        let cp = cprune(&model, &sim, &mut ProxyOracle::new(), &cfg);
        blocks.push(Table2Block {
            device: "Kryo 280",
            rows: vec![orig, outcome_of("CPrune", &cp)],
        });
    }

    // Kryo 585: CPrune + both ablations.
    {
        let sim = Simulator::new(DeviceSpec::kryo585());
        let session = TuningSession::new(&sim, scale.tune_opts(), seed);
        let (orig, _) = original_row(&model, &session);
        let base = CPruneConfig {
            max_iterations: scale.cprune_iters(),
            tune_opts: scale.tune_opts(),
            seed,
            alpha: 0.97,
            target_accuracy: crate::exp::paper_accuracy_budget(ModelKind::ResNet18Cifar),
            ..Default::default()
        };
        let cp = cprune(&model, &sim, &mut ProxyOracle::new(), &base);
        let wo_tuning = cprune(
            &model,
            &sim,
            &mut ProxyOracle::new(),
            // same search effort as the tuned run (Fig. 10's comparison)
            &CPruneConfig {
                with_tuning: false,
                max_candidates: cp.candidates_tried,
                ..base.clone()
            },
        );
        let single = cprune(
            &model,
            &sim,
            &mut ProxyOracle::new(),
            // same candidate budget the associated run consumed: Fig. 9's
            // fixed-effort comparison
            &CPruneConfig {
                associated_subgraphs: false,
                max_candidates: cp.candidates_tried,
                ..base
            },
        );
        blocks.push(Table2Block {
            device: "Kryo 585",
            rows: vec![
                orig,
                outcome_of("CPrune", &cp),
                outcome_of("CPrune (w/o tuning)", &wo_tuning),
                outcome_of("CPrune (single subgraph pruning)", &single),
            ],
        });
    }
    blocks
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_shape() {
        let blocks = run(Scale::Smoke, 2);
        assert_eq!(blocks.len(), 2);
        for b in &blocks {
            let orig = &b.rows[0];
            let cp = &b.rows[1];
            assert!(cp.fps > orig.fps, "{}: CPrune not faster", b.device);
            assert!(cp.macs < orig.macs);
            // CIFAR accuracy cost is small
            assert!(cp.top1 > 0.9437 - 0.04, "{}: top1 {}", b.device, cp.top1);
        }
    }
}
