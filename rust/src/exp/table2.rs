//! Table 2 — ResNet-18 / CIFAR-10 on mobile CPUs: CPrune and its
//! ablations.
//!
//! Paper shape: Kryo 280 → 3.24×, Kryo 585 → 2.31×; w/o tuning only
//! 1.43×; single-subgraph pruning 1.97× — with top-1 within ~0.7 pp of
//! the 94.37 % original.
//!
//! All rows run through the uniform [`crate::run::Pruner`] trait on one
//! [`RunBuilder`] per device; the ablations are just relabeled
//! [`CPrune`] configs looped over like any other pruner (DESIGN.md §9).

use crate::baselines::Outcome;
use crate::device::DeviceSpec;
use crate::exp::Scale;
use crate::graph::model_zoo::ModelKind;
use crate::pruner::CPruneConfig;
use crate::run::{CPrune, Run, RunBuilder};

#[derive(Debug)]
pub struct Table2Block {
    pub device: &'static str,
    pub rows: Vec<Outcome>,
}

fn cifar_run(spec: DeviceSpec, scale: Scale, seed: u64) -> Run {
    RunBuilder::new(ModelKind::ResNet18Cifar)
        .device_spec(spec)
        .seed(seed)
        .tune_opts(scale.tune_opts())
        .build()
        .expect("zoo model + known device") // cprune-lint: allow(CPL005, reason="experiment drivers abort loudly by design")
}

fn cifar_cfg(scale: Scale, seed: u64) -> CPruneConfig {
    CPruneConfig {
        max_iterations: scale.cprune_iters(),
        tune_opts: scale.tune_opts(),
        seed,
        // CIFAR tolerates deep pruning (paper prunes to 29% of MACs)
        alpha: 0.97,
        target_accuracy: crate::exp::paper_accuracy_budget(ModelKind::ResNet18Cifar),
        ..Default::default()
    }
}

pub fn run(scale: Scale, seed: u64) -> Vec<Table2Block> {
    let mut blocks = Vec::new();

    // Kryo 280: plain CPrune row.
    {
        let mut run = cifar_run(DeviceSpec::kryo280(), scale, seed);
        let (orig, _) = run.original_row();
        let cp = run
            .execute(&CPrune::with_cfg(cifar_cfg(scale, seed)))
            .expect("cprune run"); // cprune-lint: allow(CPL005, reason="experiment drivers abort loudly by design")
        blocks.push(Table2Block {
            device: "Kryo 280",
            rows: vec![orig, cp.to_outcome()],
        });
    }

    // Kryo 585: CPrune + both ablations.
    {
        let mut run = cifar_run(DeviceSpec::kryo585(), scale, seed);
        let (orig, _) = run.original_row();
        let base = cifar_cfg(scale, seed);
        let cp = run
            .execute(&CPrune::with_cfg(base.clone()))
            .expect("cprune run"); // cprune-lint: allow(CPL005, reason="experiment drivers abort loudly by design")
        // Both ablations get the same search effort the tuned associated
        // run consumed (Figs. 9/10's fixed-budget comparisons).
        let ablations = [
            CPrune::with_cfg(CPruneConfig {
                with_tuning: false,
                max_candidates: cp.search_candidates,
                ..base.clone()
            })
            .with_label("CPrune (w/o tuning)"),
            CPrune::with_cfg(CPruneConfig {
                associated_subgraphs: false,
                max_candidates: cp.search_candidates,
                ..base
            })
            .with_label("CPrune (single subgraph pruning)"),
        ];
        let mut rows = vec![orig, cp.to_outcome()];
        for pruner in &ablations {
            rows.push(run.execute(pruner).expect("ablation run").to_outcome()); // cprune-lint: allow(CPL005, reason="experiment drivers abort loudly by design")
        }
        blocks.push(Table2Block { device: "Kryo 585", rows });
    }
    blocks
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_shape() {
        let blocks = run(Scale::Smoke, 2);
        assert_eq!(blocks.len(), 2);
        for b in &blocks {
            let orig = &b.rows[0];
            let cp = &b.rows[1];
            assert!(cp.fps > orig.fps, "{}: CPrune not faster", b.device);
            assert!(cp.macs < orig.macs);
            // CIFAR accuracy cost is small
            assert!(cp.top1 > 0.9437 - 0.04, "{}: top1 {}", b.device, cp.top1);
        }
        // the Kryo 585 block carries both ablation rows
        assert_eq!(blocks[1].rows.len(), 4);
        assert!(blocks[1].rows[2].method.contains("w/o tuning"));
        assert!(blocks[1].rows[3].method.contains("single subgraph"));
    }
}
