//! Fig. 1 — the motivation experiment.
//!
//! Twenty randomly pruned VGG-16/CIFAR-10 variants. For each: accuracy
//! (proxy with seeded jitter — the paper's models differ by training
//! noise too), FPS *before* compiler optimization (library-default
//! schedules) and *after* (auto-tuned). The paper's claims to reproduce:
//!
//! 1. the best-before model (meeting the 92.80 % gate) is NOT the
//!    best-after model;
//! 2. there is no strong before/after correlation.

use crate::accuracy::{AccuracyOracle, Criterion, ProxyOracle, TrainPhase};
use crate::baselines::magnitude::random_variant;
use crate::baselines::{fps_of_state, fps_of_state_untuned};
use crate::device::{DeviceSpec, Simulator};
use crate::exp::Scale;
use crate::graph::model_zoo::{Model, ModelKind};
use crate::pruner::summarize;
use crate::tuner::TuningSession;
use crate::util::stats::{pearson, spearman};

/// One pruned variant's row.
#[derive(Clone, Debug)]
pub struct VariantRow {
    pub id: usize,
    pub top1: f64,
    pub fps_before: f64,
    pub fps_after: f64,
    pub meets_gate: bool,
}

#[derive(Clone, Debug)]
pub struct Fig1Result {
    pub rows: Vec<VariantRow>,
    pub accuracy_gate: f64,
    /// Index of the fastest gate-meeting model before compilation ("A").
    pub best_before: usize,
    /// Index of the fastest gate-meeting model after compilation ("B").
    pub best_after: usize,
    pub pearson_r: f64,
    pub spearman_rho: f64,
}

pub fn run(scale: Scale, n_variants: usize, seed: u64) -> Fig1Result {
    let model = Model::build(ModelKind::Vgg16Cifar, seed);
    let sim = Simulator::new(DeviceSpec::rtx3080());
    let session = TuningSession::new(&sim, scale.tune_opts(), seed);
    let mut oracle = ProxyOracle::with_jitter(0.0015, seed);
    let accuracy_gate = 0.9280;

    let mut rows = Vec::with_capacity(n_variants);
    for i in 0..n_variants {
        let state = random_variant(&model, 0.6, seed * 1000 + i as u64);
        let summary = summarize(&model, &state, Criterion::Random);
        let top1 = oracle.top1(&summary, TrainPhase::Final);
        let fps_before = fps_of_state_untuned(&model, &state, &sim);
        let fps_after = fps_of_state(&model, &state, &session);
        rows.push(VariantRow {
            id: i,
            top1,
            fps_before,
            fps_after,
            meets_gate: top1 >= accuracy_gate,
        });
    }

    let argmax = |f: &dyn Fn(&VariantRow) -> f64| -> usize {
        rows.iter()
            .filter(|r| r.meets_gate)
            .max_by(|a, b| f(a).total_cmp(&f(b)))
            .map(|r| r.id)
            .unwrap_or(0)
    };
    let best_before = argmax(&|r: &VariantRow| r.fps_before);
    let best_after = argmax(&|r: &VariantRow| r.fps_after);
    let xs: Vec<f64> = rows.iter().map(|r| r.fps_before).collect();
    let ys: Vec<f64> = rows.iter().map(|r| r.fps_after).collect();

    Fig1Result {
        accuracy_gate,
        best_before,
        best_after,
        pearson_r: pearson(&xs, &ys),
        spearman_rho: spearman(&xs, &ys),
        rows,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_shape_holds() {
        let r = run(Scale::Smoke, 12, 3);
        assert_eq!(r.rows.len(), 12);
        // compiled FPS dwarfs uncompiled FPS on the host GPU (paper: ~200
        // FPS before vs ~2800 after)
        let any_big_speedup = r
            .rows
            .iter()
            .any(|row| row.fps_after > 3.0 * row.fps_before);
        assert!(any_big_speedup, "compiler optimization gains too small");
        // correlation is weak (the paper's central observation)
        assert!(
            r.spearman_rho < 0.95,
            "before/after ordering suspiciously identical: {}",
            r.spearman_rho
        );
        // at least some variants meet the accuracy gate
        assert!(r.rows.iter().filter(|x| x.meets_gate).count() >= 2);
    }
}
