//! Fig. 11 — selective (CPrune) vs exhaustive (NetAdapt-style) search.
//!
//! Paper shape: CPrune's prioritized, selective task search costs ~10 %
//! of the exhaustive per-layer measurement loop in Main-step time while
//! reaching similar or better FPS.
//!
//! Both searches run through the uniform [`crate::run::Pruner`] trait on
//! one [`RunBuilder`] wiring (DESIGN.md §9).

use crate::baselines::netadapt::NetAdaptConfig;
use crate::exp::Scale;
use crate::graph::model_zoo::ModelKind;
use crate::pruner::CPruneConfig;
use crate::run::{CPrune, NetAdapt, RunBuilder};

#[derive(Debug)]
pub struct Fig11Result {
    pub cprune_fps: f64,
    pub exhaustive_fps: f64,
    /// Candidate models evaluated by each search (the cost Fig. 11 plots).
    pub cprune_candidates: usize,
    pub exhaustive_candidates: usize,
    pub cprune_seconds: f64,
    pub exhaustive_seconds: f64,
}

pub fn run(scale: Scale, seed: u64) -> Fig11Result {
    let kind = ModelKind::ResNet18ImageNet;
    let mut run = RunBuilder::new(kind)
        .device("kryo585")
        .seed(seed)
        .tune_opts(scale.tune_opts())
        .build()
        .expect("zoo model + known device"); // cprune-lint: allow(CPL005, reason="experiment drivers abort loudly by design")

    let cfg = CPruneConfig {
        max_iterations: scale.cprune_iters(),
        tune_opts: scale.tune_opts(),
        seed,
        target_accuracy: crate::exp::paper_accuracy_budget(kind),
        ..Default::default()
    };
    let cp = run.execute(&CPrune::with_cfg(cfg)).expect("cprune run"); // cprune-lint: allow(CPL005, reason="experiment drivers abort loudly by design")

    // Exhaustive: NetAdapt driven to a comparable latency target.
    let target_ratio = (1.0 / cp.fps_increase_rate).clamp(0.3, 0.95);
    let na_cfg = NetAdaptConfig {
        target_latency_ratio: target_ratio,
        max_iterations: scale.cprune_iters(),
        ..Default::default()
    };
    let na = run.execute(&NetAdapt::with(na_cfg)).expect("netadapt run"); // cprune-lint: allow(CPL005, reason="experiment drivers abort loudly by design")

    Fig11Result {
        cprune_fps: cp.final_fps,
        exhaustive_fps: na.final_fps,
        cprune_candidates: cp.search_candidates,
        exhaustive_candidates: na.search_candidates,
        cprune_seconds: cp.main_step_seconds,
        exhaustive_seconds: na.main_step_seconds,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selective_search_is_cheaper() {
        let r = run(Scale::Smoke, 5);
        assert!(
            r.cprune_candidates <= r.exhaustive_candidates,
            "selective {} vs exhaustive {}",
            r.cprune_candidates,
            r.exhaustive_candidates
        );
        // similar or better quality
        assert!(r.cprune_fps > 0.0 && r.exhaustive_fps > 0.0);
    }
}
