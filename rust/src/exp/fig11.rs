//! Fig. 11 — selective (CPrune) vs exhaustive (NetAdapt-style) search.
//!
//! Paper shape: CPrune's prioritized, selective task search costs ~10 %
//! of the exhaustive per-layer measurement loop in Main-step time while
//! reaching similar or better FPS.

use crate::accuracy::ProxyOracle;
use crate::baselines::netadapt::{netadapt, NetAdaptConfig};
use crate::device::{DeviceSpec, Simulator};
use crate::exp::Scale;
use crate::graph::model_zoo::{Model, ModelKind};
use crate::pruner::{cprune, CPruneConfig};
use crate::tuner::TuningSession;

#[derive(Debug)]
pub struct Fig11Result {
    pub cprune_fps: f64,
    pub exhaustive_fps: f64,
    /// Candidate models evaluated by each search (the cost Fig. 11 plots).
    pub cprune_candidates: usize,
    pub exhaustive_candidates: usize,
    pub cprune_seconds: f64,
    pub exhaustive_seconds: f64,
}

pub fn run(scale: Scale, seed: u64) -> Fig11Result {
    let model = Model::build(ModelKind::ResNet18ImageNet, seed);
    let sim = Simulator::new(DeviceSpec::kryo585());

    let mut oracle = ProxyOracle::new();
    let cfg = CPruneConfig {
        max_iterations: scale.cprune_iters(),
        tune_opts: scale.tune_opts(),
        seed,
        target_accuracy: crate::exp::paper_accuracy_budget(ModelKind::ResNet18ImageNet),
        ..Default::default()
    };
    let cp = cprune(&model, &sim, &mut oracle, &cfg);

    // Exhaustive: NetAdapt driven to a comparable latency target.
    let target_ratio = (1.0 / cp.fps_increase_rate).clamp(0.3, 0.95);
    let session = TuningSession::new(&sim, scale.tune_opts(), seed);
    let mut oracle = ProxyOracle::new();
    let na_cfg = NetAdaptConfig {
        target_latency_ratio: target_ratio,
        max_iterations: scale.cprune_iters(),
        ..Default::default()
    };
    let na = netadapt(&model, &session, &sim, &mut oracle, &na_cfg);

    Fig11Result {
        cprune_fps: cp.final_fps,
        exhaustive_fps: na.outcome.fps,
        cprune_candidates: cp.candidates_tried,
        exhaustive_candidates: na.candidates_tried,
        cprune_seconds: cp.main_step_seconds,
        exhaustive_seconds: na.outcome.main_step_seconds,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selective_search_is_cheaper() {
        let r = run(Scale::Smoke, 5);
        assert!(
            r.cprune_candidates <= r.exhaustive_candidates,
            "selective {} vs exhaustive {}",
            r.cprune_candidates,
            r.exhaustive_candidates
        );
        // similar or better quality
        assert!(r.cprune_fps > 0.0 && r.exhaustive_fps > 0.0);
    }
}
